//! Replica health: heartbeats, liveness states, and the death-watch
//! protocol between workers and the supervisor (DESIGN.md §13).
//!
//! Each replica slot carries four atomics:
//!
//! * a **progress epoch**, bumped by the worker once per executed chunk
//!   ([`HealthBoard::beat`]) — the heartbeat;
//! * a **state** (`Idle`/`Busy`/`Dead`/`Retired`);
//! * a **busy-since** stamp (µs since board creation), refreshed by
//!   every beat, so the watchdog only reads `Busy` slots whose stamp is
//!   stale — a parked idle worker never trips it;
//! * an **incarnation** counter: each respawn bumps it, and every
//!   worker-side write is guarded by its own incarnation, so a
//!   superseded zombie (a thread wedged inside `forward` that the
//!   supervisor already replaced) can neither re-mark the slot nor pop
//!   another batch once it wakes — it observes it is stale at the top
//!   of its loop and exits.  This preserves the §11 one-popper-per-
//!   shard contract across respawns.
//!
//! Worker exits are reported by a [`DeathWatch`] drop guard: armed on
//! spawn, disarmed only on a clean shutdown-time exit, so panics and
//! fatal-backend exits both land in `Dead` without any happy-path
//! bookkeeping — and a stale incarnation's report is a no-op.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::util::lock;

/// Liveness state of one replica slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    /// Worker is between batches (parked or scanning) — healthy.
    Idle,
    /// Worker is executing a batch — healthy unless the busy stamp
    /// goes stale past the watchdog deadline.
    Busy,
    /// Worker exited (panic / fatal backend) or was superseded after a
    /// watchdog trip; awaiting respawn.
    Dead,
    /// Restart budget exhausted: permanently out of the pool, which
    /// now runs degraded on the survivors.
    Retired,
}

const S_IDLE: u8 = 0;
const S_BUSY: u8 = 1;
const S_DEAD: u8 = 2;
const S_RETIRED: u8 = 3;

struct Slot {
    epoch: AtomicU64,
    state: AtomicU8,
    busy_since_us: AtomicU64,
    incarnation: AtomicU64,
}

/// Shared health state for the pool: one [`Slot`] per replica plus a
/// fault log.  All hot-path operations (`beat`, `set_busy`, `alive`)
/// are a couple of relaxed atomics; nothing here is ever held across
/// an intake lock, so the §11 `shard → board` order is untouched.
pub struct HealthBoard {
    slots: Vec<Slot>,
    origin: Instant,
    /// Human-readable fault history (deaths, trips, respawns,
    /// retirements) — surfaced via `Server::fault_log` instead of
    /// failing shutdown for faults the supervisor already handled.
    // lock-order: health level 1
    faults: Mutex<Vec<String>>,
}

impl HealthBoard {
    /// A board with one slot per replica (minimum one).
    pub fn new(replicas: usize) -> Self {
        HealthBoard {
            slots: (0..replicas.max(1))
                .map(|_| Slot {
                    epoch: AtomicU64::new(0),
                    state: AtomicU8::new(S_IDLE),
                    busy_since_us: AtomicU64::new(0),
                    incarnation: AtomicU64::new(0),
                })
                .collect(),
            origin: Instant::now(),
            faults: Mutex::new(Vec::new()),
        }
    }

    /// Number of replica slots.
    pub fn replicas(&self) -> usize {
        self.slots.len()
    }

    fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    /// Heartbeat: `r` made progress (one chunk executed).  Refreshes
    /// the busy stamp so a long multi-chunk batch never trips the
    /// watchdog while it advances.
    pub fn beat(&self, r: usize) {
        if let Some(s) = self.slots.get(r) {
            s.epoch.fetch_add(1, Ordering::Relaxed);
            s.busy_since_us.store(self.now_us(), Ordering::Relaxed);
        }
    }

    /// Progress epoch of `r` (diagnostics / tests).
    pub fn epoch(&self, r: usize) -> u64 {
        self.slots.get(r).map_or(0, |s| s.epoch.load(Ordering::Relaxed))
    }

    /// Worker-side state write, guarded by the writer's incarnation so
    /// a superseded zombie cannot clobber its replacement's slot.
    fn set_state_if_current(&self, r: usize, inc: u64, state: u8) {
        if let Some(s) = self.slots.get(r) {
            if s.incarnation.load(Ordering::Acquire) == inc {
                s.state.store(state, Ordering::Release);
            }
        }
    }

    /// Worker `r`@`inc` starts executing a batch.
    pub fn set_busy(&self, r: usize, inc: u64) {
        if let Some(s) = self.slots.get(r) {
            if s.incarnation.load(Ordering::Acquire) == inc {
                s.busy_since_us.store(self.now_us(), Ordering::Relaxed);
                s.state.store(S_BUSY, Ordering::Release);
            }
        }
    }

    /// Worker `r`@`inc` is back between batches.
    pub fn set_idle(&self, r: usize, inc: u64) {
        self.set_state_if_current(r, inc, S_IDLE);
    }

    /// Report worker `r`@`inc` dead (panic or fatal backend).  A stale
    /// incarnation's report and a retired slot are both no-ops.
    pub fn mark_dead(&self, r: usize, inc: u64) {
        if let Some(s) = self.slots.get(r) {
            if s.incarnation.load(Ordering::Acquire) == inc
                && s.state.load(Ordering::Acquire) != S_RETIRED
            {
                s.state.store(S_DEAD, Ordering::Release);
            }
        }
    }

    /// Supervisor-side: invalidate the current worker of `r` (watchdog
    /// trip or respawn) and return the next incarnation.  The old
    /// thread sees itself stale at its next loop iteration and exits;
    /// the replacement is spawned carrying the returned value.
    pub fn supersede(&self, r: usize) -> u64 {
        let s = &self.slots[r];
        let inc = s.incarnation.fetch_add(1, Ordering::AcqRel) + 1;
        s.state.store(S_DEAD, Ordering::Release);
        inc
    }

    /// Is `inc` still the live incarnation of `r`?  Workers check this
    /// at the top of their serve loop, *before* popping a batch.
    pub fn is_current(&self, r: usize, inc: u64) -> bool {
        self.slots
            .get(r)
            .map_or(false, |s| s.incarnation.load(Ordering::Acquire) == inc)
    }

    /// Current incarnation of `r`.
    pub fn incarnation(&self, r: usize) -> u64 {
        self.slots.get(r).map_or(0, |s| s.incarnation.load(Ordering::Acquire))
    }

    /// Permanently retire `r` (restart budget exhausted).
    pub fn retire(&self, r: usize) {
        if let Some(s) = self.slots.get(r) {
            s.state.store(S_RETIRED, Ordering::Release);
        }
    }

    /// Current lifecycle state of replica `r` (out of range reads as
    /// retired).
    pub fn state(&self, r: usize) -> ReplicaState {
        match self.slots.get(r).map_or(S_RETIRED, |s| s.state.load(Ordering::Acquire)) {
            S_IDLE => ReplicaState::Idle,
            S_BUSY => ReplicaState::Busy,
            S_DEAD => ReplicaState::Dead,
            _ => ReplicaState::Retired,
        }
    }

    /// Is `r` routable (idle or making progress)?
    pub fn alive(&self, r: usize) -> bool {
        matches!(self.state(r), ReplicaState::Idle | ReplicaState::Busy)
    }

    /// Number of routable replicas.
    pub fn alive_count(&self) -> usize {
        (0..self.slots.len()).filter(|&r| self.alive(r)).count()
    }

    /// Watchdog predicate: `r` claims `Busy` but its stamp has not
    /// moved for longer than `watchdog` — wedged inside `forward`.
    pub fn stale_busy(&self, r: usize, watchdog: Duration) -> bool {
        let Some(s) = self.slots.get(r) else { return false };
        if s.state.load(Ordering::Acquire) != S_BUSY {
            return false;
        }
        let since = s.busy_since_us.load(Ordering::Relaxed);
        self.now_us().saturating_sub(since) > watchdog.as_micros() as u64
    }

    /// Append one line to the fault history.
    pub fn log_fault(&self, line: String) {
        lock(&self.faults).push(line);
    }

    /// Snapshot of the fault history (deaths, trips, respawns,
    /// retirements since startup).
    pub fn fault_log(&self) -> Vec<String> {
        lock(&self.faults).clone()
    }
}

/// Drop guard a worker thread holds for its whole life: armed on
/// spawn, disarmed only on the clean shutdown-time exit, so *any*
/// other way out — panic anywhere in the serve loop, fatal backend —
/// marks the slot `Dead` for the supervisor.  Incarnation-guarded like
/// every worker-side write.
pub struct DeathWatch {
    board: Arc<HealthBoard>,
    replica: usize,
    incarnation: u64,
    armed: bool,
}

impl DeathWatch {
    /// Arm a watch: unless [`disarm`](DeathWatch::disarm)ed, dropping
    /// it marks `replica`'s `incarnation` dead on `board`.
    pub fn new(board: Arc<HealthBoard>, replica: usize, incarnation: u64) -> Self {
        DeathWatch { board, replica, incarnation, armed: true }
    }

    /// Clean exit (queue closed at shutdown): the slot stays in its
    /// last healthy state instead of reading as a death.
    pub fn disarm(&mut self) {
        self.armed = false;
    }
}

impl Drop for DeathWatch {
    fn drop(&mut self) {
        if self.armed {
            self.board.mark_dead(self.replica, self.incarnation);
        }
    }
}

/// Supervision policy (`PoolConfig::supervision`, DESIGN.md §13).
#[derive(Clone, Debug)]
pub struct SupervisionCfg {
    /// Supervisor tick — how often heartbeats are inspected.  The
    /// detection latency for a clean death is one tick.
    pub heartbeat: Duration,
    /// Watchdog deadline: a `Busy` replica whose progress stamp is
    /// older than this is declared wedged and superseded.  Must
    /// comfortably exceed the slowest expected batch (beats refresh
    /// the stamp per chunk, so this bounds one *chunk*, not a batch).
    pub watchdog: Duration,
    /// Respawn attempts per replica before it is retired for good.
    pub max_restarts: u32,
    /// First respawn delay; doubles per consecutive attempt.
    pub backoff: Duration,
    /// Ceiling on the doubled backoff.
    pub backoff_cap: Duration,
}

impl Default for SupervisionCfg {
    fn default() -> Self {
        SupervisionCfg {
            heartbeat: Duration::from_millis(25),
            watchdog: Duration::from_secs(2),
            max_restarts: 3,
            backoff: Duration::from_millis(25),
            backoff_cap: Duration::from_secs(1),
        }
    }
}

impl SupervisionCfg {
    /// Reject configurations the supervisor cannot safely run with.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.heartbeat > Duration::ZERO && self.heartbeat <= Duration::from_secs(10),
            "supervision heartbeat must be in (0, 10s], got {:?}",
            self.heartbeat
        );
        ensure!(
            self.watchdog >= self.heartbeat,
            "supervision watchdog {:?} must be >= the heartbeat tick {:?} \
             (a sub-tick deadline can never be observed)",
            self.watchdog,
            self.heartbeat
        );
        ensure!(
            self.backoff > Duration::ZERO && self.backoff_cap >= self.backoff,
            "supervision backoff must be > 0 and <= its cap, got {:?} / {:?}",
            self.backoff,
            self.backoff_cap
        );
        Ok(())
    }

    /// Delay before respawn attempt `attempt` (1-based): exponential
    /// from `backoff`, capped at `backoff_cap`.
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(20);
        self.backoff
            .saturating_mul(1u32 << shift)
            .min(self.backoff_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beat_advances_epoch_and_refreshes_stamp() {
        let b = HealthBoard::new(2);
        assert_eq!(b.epoch(0), 0);
        b.beat(0);
        b.beat(0);
        assert_eq!(b.epoch(0), 2);
        assert_eq!(b.epoch(1), 0);
        b.beat(9); // phantom replica: no-op, no panic
    }

    #[test]
    fn state_machine_and_alive_counting() {
        let b = HealthBoard::new(3);
        assert_eq!(b.alive_count(), 3);
        b.set_busy(1, 0);
        assert_eq!(b.state(1), ReplicaState::Busy);
        assert!(b.alive(1));
        b.mark_dead(1, 0);
        assert_eq!(b.state(1), ReplicaState::Dead);
        assert_eq!(b.alive_count(), 2);
        b.retire(1);
        assert_eq!(b.state(1), ReplicaState::Retired);
        // a retired slot cannot be resurrected by a late death report
        b.mark_dead(1, 0);
        assert_eq!(b.state(1), ReplicaState::Retired);
    }

    #[test]
    fn supersede_invalidates_the_old_incarnation() {
        let b = HealthBoard::new(1);
        assert!(b.is_current(0, 0));
        let inc = b.supersede(0);
        assert_eq!(inc, 1);
        assert!(!b.is_current(0, 0), "zombie must observe it is stale");
        assert!(b.is_current(0, 1));
        assert_eq!(b.state(0), ReplicaState::Dead);
        // the zombie's late writes are all no-ops now
        b.set_busy(0, 0);
        b.set_idle(0, 0);
        b.mark_dead(0, 0);
        assert_eq!(b.state(0), ReplicaState::Dead);
        // …while the replacement's writes land
        b.set_idle(0, 1);
        assert_eq!(b.state(0), ReplicaState::Idle);
    }

    #[test]
    fn watchdog_only_trips_stale_busy_slots() {
        let b = HealthBoard::new(2);
        // idle slots never trip, however old
        assert!(!b.stale_busy(0, Duration::ZERO));
        b.set_busy(0, 0);
        assert!(!b.stale_busy(0, Duration::from_secs(60)));
        std::thread::sleep(Duration::from_millis(5));
        assert!(b.stale_busy(0, Duration::from_millis(1)));
        // a beat refreshes the stamp and clears the staleness
        b.beat(0);
        assert!(!b.stale_busy(0, Duration::from_millis(1)));
    }

    #[test]
    fn death_watch_reports_unless_disarmed_and_respects_incarnation() {
        let b = Arc::new(HealthBoard::new(2));
        // armed drop (panic path) marks dead
        drop(DeathWatch::new(Arc::clone(&b), 0, 0));
        assert_eq!(b.state(0), ReplicaState::Dead);
        // disarmed drop (clean shutdown) does not
        let mut w = DeathWatch::new(Arc::clone(&b), 1, 0);
        w.disarm();
        drop(w);
        assert_eq!(b.state(1), ReplicaState::Idle);
        // a superseded incarnation's drop is a no-op
        let w = DeathWatch::new(Arc::clone(&b), 1, 0);
        let inc = b.supersede(1);
        b.set_idle(1, inc);
        drop(w);
        assert_eq!(b.state(1), ReplicaState::Idle);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let cfg = SupervisionCfg {
            backoff: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(65),
            ..SupervisionCfg::default()
        };
        assert_eq!(cfg.backoff_for(1), Duration::from_millis(10));
        assert_eq!(cfg.backoff_for(2), Duration::from_millis(20));
        assert_eq!(cfg.backoff_for(3), Duration::from_millis(40));
        assert_eq!(cfg.backoff_for(4), Duration::from_millis(65));
        assert_eq!(cfg.backoff_for(31), Duration::from_millis(65));
    }

    #[test]
    fn supervision_cfg_validation_is_descriptive() {
        assert!(SupervisionCfg::default().validate().is_ok());
        let bad = SupervisionCfg { heartbeat: Duration::ZERO, ..SupervisionCfg::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("heartbeat"));
        let bad = SupervisionCfg {
            watchdog: Duration::from_millis(1),
            ..SupervisionCfg::default()
        };
        assert!(bad.validate().unwrap_err().to_string().contains("watchdog"));
        let bad = SupervisionCfg { backoff: Duration::ZERO, ..SupervisionCfg::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("backoff"));
    }

    #[test]
    fn fault_log_accumulates() {
        let b = HealthBoard::new(1);
        assert!(b.fault_log().is_empty());
        b.log_fault("replica 0 died".into());
        b.log_fault("replica 0 respawned".into());
        assert_eq!(b.fault_log().len(), 2);
    }
}
