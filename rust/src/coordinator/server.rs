//! Inference server: bounded intake queue -> dynamic batcher -> a pool
//! of replica workers over a pluggable [`InferenceBackend`] -> per-
//! request responses (DESIGN.md §9).
//!
//! Each replica thread owns its own backend instance (PJRT handles are
//! not shared across threads; the factory runs on the replica's thread)
//! and pulls batches from the shared intake queue, so batching still
//! amortizes per replica while independent replicas execute in
//! parallel.  A readiness handshake makes startup failures surface from
//! [`Server::start_pool`] instead of vanishing into a dead thread, and
//! [`Server::shutdown`] returns any worker error after the drain.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::qat::QuantConfig;
use crate::runtime::Manifest;
use crate::tensor::Tensor;
use crate::util::threadpool::payload_msg;

use super::backend::{BackendFactory, InferenceBackend, PjrtBackend};
use super::batcher::{assemble_shared, Assembled, Policy, Request};
use super::metrics::{Metrics, Snapshot};

/// One image in, one class index out.
type Payload = Vec<f32>;
type Reply = std::result::Result<usize, String>;

/// PJRT server configuration ([`Server::start`]).
#[derive(Clone)]
pub struct ServerConfig {
    pub model: String,
    pub qcfg: QuantConfig,
    pub policy: Policy,
    pub queue_cap: usize,
    /// Use the Pallas-kernel fwd artifact if available.
    pub pallas: bool,
    /// Worker replicas pulling from the shared intake (>= 1).
    pub replicas: usize,
}

/// Backend-agnostic pool configuration ([`Server::start_pool`]).
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    pub policy: Policy,
    pub queue_cap: usize,
    /// Worker replicas pulling from the shared intake (>= 1).
    pub replicas: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { policy: Policy::default(), queue_cap: 256, replicas: 1 }
    }
}

/// What a replica reports through the readiness handshake once its
/// backend is constructed and warmed.
struct Ready {
    batch: usize,
    img_elems: usize,
}

/// Running server handle.
pub struct Server {
    tx: Option<SyncSender<Request<Payload, Reply>>>,
    workers: Vec<JoinHandle<Result<()>>>,
    pub metrics: Arc<Metrics>,
    started: Instant,
    img_elems: usize,
    batch: usize,
}

impl Server {
    /// Start a PJRT-backed pool; compiles the fwd artifact on every
    /// replica before returning.  Convenience wrapper over
    /// [`Server::start_pool`] with a [`PjrtBackend`] factory.
    pub fn start(manifest: &Manifest, cfg: ServerConfig) -> Result<Server> {
        let entry = manifest.model(&cfg.model)?;
        // reconcile the batching policy with the model's static batch
        // dim up front: a `Policy::default()` of 32 against a smaller
        // compiled batch used to slice out of bounds in the worker
        let policy = Policy {
            max_batch: cfg.policy.max_batch.clamp(1, entry.batch.max(1)),
            ..cfg.policy
        };
        let factory = PjrtBackend::factory(
            manifest.clone(),
            cfg.model.clone(),
            cfg.qcfg.clone(),
            cfg.pallas,
        );
        Server::start_pool(
            PoolConfig { policy, queue_cap: cfg.queue_cap, replicas: cfg.replicas },
            factory,
        )
    }

    /// Start `pool.replicas` workers over `factory`-built backends, all
    /// pulling from one bounded intake queue.  Blocks until every
    /// replica reports ready; any replica's startup failure (backend
    /// construction error or panic) fails the whole start.
    pub fn start_pool(pool: PoolConfig, factory: BackendFactory) -> Result<Server> {
        ensure!(pool.replicas >= 1, "server needs at least one replica");
        ensure!(pool.queue_cap >= 1, "server needs a non-zero queue");
        let metrics = Arc::new(Metrics::new(pool.replicas));
        let (tx, rx) = sync_channel::<Request<Payload, Reply>>(pool.queue_cap);
        let rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) =
            std::sync::mpsc::channel::<(usize, std::result::Result<Ready, String>)>();

        let mut workers = Vec::with_capacity(pool.replicas);
        for id in 0..pool.replicas {
            let rx = Arc::clone(&rx);
            let factory = Arc::clone(&factory);
            let m = Arc::clone(&metrics);
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                replica_main(id, &rx, pool.policy, &factory, &m, ready)
            }));
        }
        drop(ready_tx);

        // readiness handshake: collect one report per replica; the
        // handshake channel closes early only if a worker died without
        // reporting (a panic outside the guarded factory call)
        let mut batch = usize::MAX;
        let mut img_elems: Option<usize> = None;
        let mut failures: Vec<String> = Vec::new();
        for _ in 0..pool.replicas {
            match ready_rx.recv() {
                Ok((id, Ok(r))) => {
                    batch = batch.min(r.batch);
                    match img_elems {
                        None => img_elems = Some(r.img_elems),
                        Some(e) if e != r.img_elems => failures.push(format!(
                            "replica {id}: backend img_elems {} disagrees with {e}",
                            r.img_elems
                        )),
                        Some(_) => {}
                    }
                }
                Ok((id, Err(msg))) => failures.push(format!("replica {id}: {msg}")),
                Err(_) => {
                    failures.push("a replica died before reporting readiness".into());
                    break;
                }
            }
        }
        if !failures.is_empty() || img_elems.is_none() {
            // close the intake and reap every worker before failing so
            // no thread outlives the failed start
            drop(tx);
            for w in workers {
                let _ = w.join();
            }
            return Err(anyhow!("server start failed: {}", failures.join("; ")));
        }

        Ok(Server {
            tx: Some(tx),
            workers,
            metrics,
            started: Instant::now(),
            img_elems: img_elems.unwrap(),
            batch,
        })
    }

    /// Blocking single-request inference (returns predicted class).
    pub fn infer(&self, image: Vec<f32>) -> Result<usize> {
        let rx = self.submit(image)?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped request"))?
            .map_err(|e| anyhow!(e))
    }

    /// Async submit; returns the response channel.  Rejects payloads of
    /// the wrong length before they enter the queue.
    pub fn submit(&self, image: Vec<f32>) -> Result<std::sync::mpsc::Receiver<Reply>> {
        if image.len() != self.img_elems {
            return Err(anyhow!("image must have {} elements", self.img_elems));
        }
        self.submit_unchecked(image)
    }

    /// Async submit without the payload-length precheck.  The worker
    /// validates defensively and answers `Err` for malformed payloads —
    /// it never zero-pads them into a fabricated class — so this is
    /// safe for callers that assemble [`Request`]s from untrusted
    /// sources (and for tests of exactly that path).
    pub fn submit_unchecked(&self, image: Vec<f32>)
                            -> Result<std::sync::mpsc::Receiver<Reply>> {
        let (rtx, rrx) = std::sync::mpsc::channel();
        let tx = self.tx.as_ref().ok_or_else(|| anyhow!("server stopped"))?;
        // gauge up BEFORE send: a replica may dequeue the request the
        // instant send returns, and its queue_pop must never observe
        // the gauge without this request counted (the pop saturates, so
        // a lost decrement would otherwise stick forever)
        self.metrics.queue_push();
        tx.send(Request { payload: image, enqueued: Instant::now(), respond: rtx })
            .map_err(|_| {
                self.metrics.queue_pop(1);
                anyhow!("server worker exited")
            })?;
        Ok(rrx)
    }

    /// Smallest static batch dim across replicas.
    pub fn max_batch(&self) -> usize {
        self.batch
    }

    /// Flattened elements per image, as reported by the replicas.
    pub fn img_elems(&self) -> usize {
        self.img_elems
    }

    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// Stop accepting requests, drain the queue, join every replica,
    /// and return the final metrics — or the first worker error, which
    /// the pre-§9 server silently discarded.
    pub fn shutdown(mut self) -> Result<Snapshot> {
        drop(self.tx.take());
        let mut errs: Vec<String> = Vec::new();
        for (id, w) in self.workers.drain(..).enumerate() {
            match w.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => errs.push(format!("replica {id}: {e:#}")),
                Err(p) => errs.push(format!("replica {id} panicked: {}", payload_msg(&*p))),
            }
        }
        let elapsed = self.started.elapsed().as_secs_f64();
        let snap = self.metrics.snapshot(elapsed);
        if errs.is_empty() {
            Ok(snap)
        } else {
            Err(anyhow!("server shutdown with worker errors: {}", errs.join("; ")))
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        self.metrics
            .snapshot(self.started.elapsed().as_secs_f64())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One replica thread: construct the backend (reporting the outcome
/// through the readiness handshake), then assemble/execute until the
/// intake closes and drains.
fn replica_main(id: usize, rx: &Mutex<Receiver<Request<Payload, Reply>>>,
                policy: Policy, factory: &BackendFactory, m: &Metrics,
                ready: Sender<(usize, std::result::Result<Ready, String>)>)
                -> Result<()> {
    // the whole pre-report prelude (factory AND the geometry calls on
    // the fresh trait object) is guarded: a panic anywhere before the
    // handshake message would otherwise leave start_pool blocked on a
    // report that never comes
    let prelude = catch_unwind(AssertUnwindSafe(
        || -> Result<(Box<dyn InferenceBackend>, usize, usize)> {
            let backend = (**factory)(id)?;
            let batch = backend.batch().max(1);
            let img_elems = backend.img_elems();
            Ok((backend, batch, img_elems))
        },
    ));
    let (mut backend, batch, img_elems) = match prelude {
        Ok(Ok(t)) => t,
        Ok(Err(e)) => {
            let msg = format!("{e:#}");
            let _ = ready.send((id, Err(msg.clone())));
            return Err(anyhow!("backend startup failed: {msg}"));
        }
        Err(p) => {
            let msg = format!("backend startup panicked: {}", payload_msg(&*p));
            let _ = ready.send((id, Err(msg.clone())));
            return Err(anyhow!(msg));
        }
    };
    // per-replica clamp of the batching policy to this backend's static
    // batch dim (`Server::start` clamps from the manifest too; custom
    // factories get the same guarantee here)
    let policy = Policy { max_batch: policy.max_batch.clamp(1, batch), ..policy };
    let _ = ready.send((id, Ok(Ready { batch, img_elems })));
    // release the handshake channel NOW: holding it for the serving
    // lifetime would keep start_pool's recv() from ever seeing closure
    // if a sibling replica died without reporting
    drop(ready);
    loop {
        match assemble_shared(rx, policy) {
            Assembled::Closed => return Ok(()),
            Assembled::Batch(reqs) => {
                m.queue_pop(reqs.len());
                execute_assembly(backend.as_mut(), id, &reqs, m);
            }
        }
    }
}

/// Execute one assembled batch on a backend: validate payloads, split
/// oversized assemblies, pad, forward, argmax, reply.  Infallible by
/// construction — every request gets exactly one reply and backend
/// errors/panics are converted into error replies, never worker death.
fn execute_assembly(backend: &mut dyn InferenceBackend, id: usize,
                    reqs: &[Request<Payload, Reply>], m: &Metrics) {
    let batch = backend.batch().max(1);
    let img_elems = backend.img_elems();
    // a request whose payload length is wrong gets an Err reply; it is
    // never zero-padded and answered with a fabricated class (submit
    // validates, but `Request` is public and the batcher is reusable)
    let (valid, invalid): (Vec<_>, Vec<_>) = reqs
        .iter()
        .partition(|r| r.payload.len() == img_elems);
    for r in invalid {
        let _ = r.respond.send(Err(format!(
            "payload has {} elements, model wants {img_elems}",
            r.payload.len()
        )));
        m.record_rejected();
    }
    // defensive split: an assembly larger than the backend's static
    // batch dim (mis-clamped policy, future policy bugs) is executed in
    // chunks instead of slicing `xdata` out of bounds
    for chunk in valid.chunks(batch) {
        let t0 = Instant::now();
        let n = chunk.len();
        // pad to the static batch dim
        let mut xdata = vec![0.0f32; batch * img_elems];
        for (i, r) in chunk.iter().enumerate() {
            xdata[i * img_elems..(i + 1) * img_elems].copy_from_slice(&r.payload);
        }
        let out = Tensor::new(vec![batch, img_elems], xdata)
            .and_then(|x| {
                // a backend panic fails the chunk, not the replica: the
                // queued clients behind it must still be answered
                match catch_unwind(AssertUnwindSafe(|| backend.forward(x))) {
                    Ok(r) => r,
                    Err(p) => Err(anyhow!("backend panicked: {}", payload_msg(&*p))),
                }
            })
            .and_then(|logits| {
                ensure!(
                    logits.rank() == 2 && logits.shape[0] >= n,
                    "backend returned logits shaped {:?} for a {n}-request chunk",
                    logits.shape
                );
                Ok(logits)
            });
        let dt = t0.elapsed().as_secs_f64();
        match out {
            Ok(logits) => {
                let preds = logits.argmax_rows();
                for (i, r) in chunk.iter().enumerate() {
                    let _ = r.respond.send(Ok(preds[i]));
                }
                m.record_batch(id, n, dt, batch - n);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in chunk {
                    let _ = r.respond.send(Err(msg.clone()));
                }
                // failed batches are accounted too: the error counters
                // + their wall time
                m.record_error(id, n, dt);
            }
        }
    }
}

/// Closed-loop load generator: `clients` threads each issue `per_client`
/// sequential requests of synthetic images; returns the final snapshot.
pub fn load_test(server: &Server, clients: usize, per_client: usize,
                 img_elems: usize) -> Result<()> {
    let _ = server.metrics.requests.load(Ordering::Relaxed);
    std::thread::scope(|scope| {
        for c in 0..clients {
            scope.spawn(move || {
                let mut rng = crate::util::rng::Rng::new(100 + c as u64);
                for _ in 0..per_client {
                    let img = rng.normal_vec(img_elems);
                    if let Ok(rx) = server.submit(img) {
                        let _ = rx.recv_timeout(Duration::from_secs(120));
                    }
                }
            });
        }
    });
    Ok(())
}
