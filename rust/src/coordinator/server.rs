//! Inference server: bounded intake queue -> dynamic batcher -> PJRT
//! worker executing the quantized fwd HLO -> per-request responses.
//!
//! The worker thread owns the Session + Executor (PJRT handles are not
//! shared across threads); clients talk through channels.  This is the
//! deployment shape of the paper's accelerator: DyBit quantization config
//! is chosen once (by the search framework) and applied as runtime inputs
//! on every batch.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::qat::{QuantConfig, Session};
use crate::runtime::{Executor, Manifest};
use crate::tensor::Tensor;

use super::batcher::{assemble, Assembled, Policy, Request};
use super::metrics::{Metrics, Snapshot};

/// One image in, one class index out.
type Payload = Vec<f32>;
type Reply = Result<usize, String>;

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    pub model: String,
    pub qcfg: QuantConfig,
    pub policy: Policy,
    pub queue_cap: usize,
    /// Use the Pallas-kernel fwd artifact if available.
    pub pallas: bool,
}

/// Running server handle.
pub struct Server {
    tx: Option<SyncSender<Request<Payload, Reply>>>,
    worker: Option<JoinHandle<Result<()>>>,
    pub metrics: Arc<Metrics>,
    started: Instant,
    img_elems: usize,
    batch: usize,
}

impl Server {
    /// Start the worker; compiles the fwd artifact before returning.
    pub fn start(manifest: &Manifest, cfg: ServerConfig) -> Result<Server> {
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        let entry = manifest
            .models
            .get(&cfg.model)
            .ok_or_else(|| anyhow!("unknown model {}", cfg.model))?;
        let batch = entry.batch;
        let img_elems: usize = entry.input.iter().skip(1).product();
        let input_shape = entry.input.clone();
        let (tx, rx) = sync_channel::<Request<Payload, Reply>>(cfg.queue_cap);

        let manifest = manifest.clone();
        let worker = std::thread::spawn(move || -> Result<()> {
            let mut exec = Executor::new(&manifest.dir)?;
            let mut session = Session::new(&manifest, &cfg.model)?;
            // compile before serving so the first request isn't a stall
            let tag = if cfg.pallas { "fwd_pallas" } else { "fwd" };
            let art = session.model.artifact(tag)?.file.clone();
            exec.load(&art)?;
            loop {
                match assemble(&rx, cfg.policy) {
                    Assembled::Closed => return Ok(()),
                    Assembled::Batch(reqs) => {
                        let t0 = Instant::now();
                        let n = reqs.len();
                        // pad to the static batch dim
                        let mut xdata = vec![0.0f32; batch * img_elems];
                        for (i, r) in reqs.iter().enumerate() {
                            if r.payload.len() == img_elems {
                                xdata[i * img_elems..(i + 1) * img_elems]
                                    .copy_from_slice(&r.payload);
                            }
                        }
                        let x = Tensor::new(input_shape.clone(), xdata)?;
                        let out = session.forward(&mut exec, &cfg.qcfg, &x, cfg.pallas);
                        let dt = t0.elapsed().as_secs_f64();
                        match out {
                            Ok(logits) => {
                                let preds = logits.argmax_rows();
                                for (i, r) in reqs.iter().enumerate() {
                                    let _ = r.respond.send(Ok(preds[i]));
                                }
                                m.record_batch(n, dt, batch - n);
                            }
                            Err(e) => {
                                let msg = format!("{e:#}");
                                for r in &reqs {
                                    let _ = r.respond.send(Err(msg.clone()));
                                }
                                // failed batches are accounted too: the
                                // error counter + their wall time
                                m.record_error(dt);
                            }
                        }
                    }
                }
            }
        });

        Ok(Server {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            started: Instant::now(),
            img_elems,
            batch,
        })
    }

    /// Blocking single-request inference (returns predicted class).
    pub fn infer(&self, image: Vec<f32>) -> Result<usize> {
        let rx = self.submit(image)?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped request"))?
            .map_err(|e| anyhow!(e))
    }

    /// Async submit; returns the response channel.
    pub fn submit(&self, image: Vec<f32>) -> Result<std::sync::mpsc::Receiver<Reply>> {
        if image.len() != self.img_elems {
            return Err(anyhow!("image must have {} elements", self.img_elems));
        }
        let (rtx, rrx) = std::sync::mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("server stopped"))?
            .send(Request { payload: image, enqueued: Instant::now(), respond: rtx })
            .map_err(|_| anyhow!("server worker exited"))?;
        Ok(rrx)
    }

    pub fn max_batch(&self) -> usize {
        self.batch
    }

    /// Stop accepting requests, drain, and return final metrics.
    pub fn shutdown(mut self) -> Snapshot {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let elapsed = self.started.elapsed().as_secs_f64();
        self.metrics.snapshot(elapsed)
    }

    pub fn snapshot(&self) -> Snapshot {
        self.metrics
            .snapshot(self.started.elapsed().as_secs_f64())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Closed-loop load generator: `clients` threads each issue `per_client`
/// sequential requests of synthetic images; returns the final snapshot.
pub fn load_test(server: &Server, clients: usize, per_client: usize,
                 img_elems: usize) -> Result<()> {
    let _ = server.metrics.requests.load(Ordering::Relaxed);
    std::thread::scope(|scope| {
        for c in 0..clients {
            scope.spawn(move || {
                let mut rng = crate::util::rng::Rng::new(100 + c as u64);
                for _ in 0..per_client {
                    let img = rng.normal_vec(img_elems);
                    if let Ok(rx) = server.submit(img) {
                        let _ = rx.recv_timeout(Duration::from_secs(120));
                    }
                }
            });
        }
    });
    Ok(())
}
