//! Inference server: router → per-replica bounded queues → dynamic
//! batcher (with tail stealing) → a pool of replica workers over a
//! pluggable [`InferenceBackend`] → per-request responses
//! (DESIGN.md §9–§10).
//!
//! Each replica thread owns its own backend instance (PJRT handles are
//! not shared across threads; the factory runs on the replica's thread)
//! and assembles batches from *its own* intake queue — the
//! [`super::Router`] in [`PoolConfig`] picks the queue per request, so a
//! pool can mix fast low-bit replicas with an accurate high-bit one and
//! schedule between them (DESIGN.md §10).  Idle replicas steal from the
//! tails of sibling queues (never reordering the victim's FIFO), and a
//! low-margin reply from a fast replica can be escalated — re-enqueued
//! once on the most accurate replica, which answers instead.  A
//! readiness handshake makes startup failures surface from
//! [`Server::start_pool`] instead of vanishing into a dead thread, and
//! [`Server::shutdown`] returns any worker error after the drain.
//!
//! Overload safety lives in [`Server::submit_with`] (DESIGN.md §12): a
//! request may carry an SLA deadline and a tenant id, and the
//! [`super::admission`] layer rejects it with a typed [`Reject`] —
//! instead of blocking — when the routed queue is full, the projected
//! queue delay already exceeds the deadline, or the tenant is over its
//! fair share of the shard.  Admitted requests that still expire in
//! the queue are dropped at assembly with an `Err` reply, so every
//! submission resolves exactly once:
//! `requests + failed_requests + rejected + deadline_drops ==
//! submitted`.
//!
//! Self-healing lives in the supervisor (DESIGN.md §13, on by default
//! via [`PoolConfig::supervision`]): workers heartbeat a health board
//! per executed chunk, a supervisor thread detects dead workers (drop-
//! guard death reports) and wedged ones (a watchdog on the busy
//! stamp), respawns them through the same [`BackendFactory`] with
//! capped exponential backoff, and retires a replica whose restart
//! budget is spent — closing and draining its queue onto live floor-
//! compatible shards.  Routing (`route_healthy`) and escalation (the
//! §13 fallback ladder) skip dead replicas, and the accounting
//! invariant above stays exact through every kill and respawn.
//!
//! Refinement (DESIGN.md §15, on by default via [`PoolConfig::refine`]):
//! when the backend decomposes into bitplanes
//! ([`InferenceBackend::planes`] > 0), an escalating replica parks the
//! low-margin rows' partial sums in a pool-wide [`PlaneCache`] and the
//! receiving replica adds only the residual planes — ~(extra-bits /
//! total-bits) of a batch instead of the 1× full re-run, which remains
//! the fallback whenever the ticket is gone (evicted, or its source
//! incarnation was superseded, §13).  Tickets are reclaimed on every
//! terminal path, and `refinements` in [`Metrics`] counts how many
//! escalations were served the cheap way.
//!
//! ```
//! use dybit::coordinator::{Escalate, PoolConfig, ReplicaPrecision, Server,
//!                          SimBackend, SimBackendCfg};
//! use std::sync::Arc;
//!
//! // three DyBit-4 replicas + one 8-bit accurate replica, low-margin
//! // replies escalated to the accurate tier
//! let mut mix = vec![ReplicaPrecision::uniform(4); 3];
//! mix.push(ReplicaPrecision::uniform(8));
//! let pool = PoolConfig {
//!     replicas: 4,
//!     precisions: mix.clone(),
//!     router: Arc::new(Escalate::new(0.1)),
//!     ..PoolConfig::default()
//! };
//! let server = Server::start_pool(
//!     pool,
//!     SimBackend::mixed_factory(SimBackendCfg::tiny(17), mix),
//! ).unwrap();
//! let class = server.infer(vec![0.25; 64]).unwrap();
//! assert!(class < 10);
//! let snap = server.shutdown().unwrap();
//! assert_eq!(
//!     snap.requests + snap.failed_requests + snap.rejected + snap.deadline_drops,
//!     1,
//! );
//! ```

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::qat::QuantConfig;
use crate::runtime::Manifest;
use crate::tensor::Tensor;
use crate::util::threadpool::payload_msg;

use super::admission::{run_margin_controller, Admission, AdmissionCfg, EscalationController,
                       Reject, SubmitOpts};
use super::backend::{BackendFactory, InferenceBackend, PjrtBackend, PlaneCache,
                     PlanePartial};
use super::batcher::{Assembled, Item, Policy, PushRefused, Request, ShardedIntake};
use super::health::{DeathWatch, HealthBoard, ReplicaState, SupervisionCfg};
use super::metrics::{Metrics, Snapshot};
use super::router::{escalation_ladder, Fastest, ReplicaPrecision, Router};

/// One image in, one class index out.
type Payload = Vec<f32>;
type Reply = std::result::Result<usize, String>;
type Intake = ShardedIntake<Payload, Reply>;

/// Bounded wait for failover pushes (escalation rungs, drain
/// re-homing): long enough to ride out a brief full queue, short
/// enough that a dead rung costs milliseconds, not a wedged worker
/// (DESIGN.md §13).
const FAILOVER_PUSH_WAIT: Duration = Duration::from_millis(25);

/// PJRT server configuration ([`Server::start`]).
#[derive(Clone)]
pub struct ServerConfig {
    /// Model name (selects the AOT artifact set).
    pub model: String,
    /// Quantization config baked into the artifact lookup.
    pub qcfg: QuantConfig,
    /// Dynamic-batching policy for every replica.
    pub policy: Policy,
    /// Per-replica intake queue capacity (submit blocks when full).
    pub queue_cap: usize,
    /// Use the Pallas-kernel fwd artifact if available.
    pub pallas: bool,
    /// Worker replicas, each with its own intake queue (>= 1).
    pub replicas: usize,
}

/// Backend-agnostic pool configuration ([`Server::start_pool`]).
#[derive(Clone)]
pub struct PoolConfig {
    /// Dynamic-batching policy for every replica.
    pub policy: Policy,
    /// Per-replica intake queue capacity (submit blocks when the routed
    /// queue is full — the same backpressure the shared intake gave).
    pub queue_cap: usize,
    /// Worker replicas (>= 1).
    pub replicas: usize,
    /// Per-replica precision (DESIGN.md §10).  Empty = homogeneous pool
    /// at the [`ReplicaPrecision`] default (8/8); otherwise one entry
    /// per replica, and the backend factory must realize the same mix
    /// (e.g. [`super::SimBackend::mixed_factory`]).
    pub precisions: Vec<ReplicaPrecision>,
    /// Per-request queue selection ([`super::router`]).  The default
    /// [`Fastest`] degrades to round-robin on homogeneous pools.
    pub router: Arc<dyn Router>,
    /// Idle replicas steal from sibling queue tails (DESIGN.md §10).
    /// Disable only to *measure* routing skew; a production pool wants
    /// this on.
    pub work_stealing: bool,
    /// SLA-aware admission for [`Server::submit_with`] (DESIGN.md §12):
    /// batch-cost seed, tenant fair-queuing buckets, projection slack.
    /// The default admits everything a plain `submit` would.
    pub admission: AdmissionCfg,
    /// Closed-loop escalation-margin tuning: when set, a background PI
    /// controller steers the pool's escalation rate onto the budget.
    /// Requires a controller-tunable router (`Escalate::auto_tuned()` /
    /// `escalate:auto`) — `start_pool` rejects the combination
    /// otherwise.
    pub escalation: Option<EscalationController>,
    /// Self-healing supervision (DESIGN.md §13): heartbeat inspection,
    /// watchdog supersede of wedged replicas, respawn with capped
    /// exponential backoff, retirement + failover drain once the
    /// restart budget is spent.  `None` disables the supervisor thread
    /// entirely — worker deaths then surface as `shutdown` errors, the
    /// pre-§13 behavior.
    pub supervision: Option<SupervisionCfg>,
    /// §15 refinement: when the backend decomposes into bitplanes
    /// ([`InferenceBackend::planes`] > 0), escalations carry a
    /// partial-sum cache ticket and the receiving replica adds only the
    /// residual planes instead of re-running from scratch.  `false`
    /// preserves the pre-§15 full re-run path (`+refine:off` in router
    /// specs); non-plane backends behave identically either way.
    pub refine: bool,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            policy: Policy::default(),
            queue_cap: 256,
            replicas: 1,
            precisions: Vec::new(),
            router: Arc::new(Fastest::new()),
            work_stealing: true,
            admission: AdmissionCfg::default(),
            escalation: None,
            supervision: Some(SupervisionCfg::default()),
            refine: true,
        }
    }
}

impl std::fmt::Debug for PoolConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolConfig")
            .field("policy", &self.policy)
            .field("queue_cap", &self.queue_cap)
            .field("replicas", &self.replicas)
            .field("precisions", &self.precisions)
            .field("router", &self.router.name())
            .field("work_stealing", &self.work_stealing)
            .field("admission", &self.admission)
            .field("escalation", &self.escalation)
            .field("supervision", &self.supervision)
            .field("refine", &self.refine)
            .finish()
    }
}

/// What a replica reports through the readiness handshake once its
/// backend is constructed and warmed.
struct Ready {
    batch: usize,
    img_elems: usize,
}

/// Everything a replica worker shares with its siblings.
struct WorkerCtx {
    queues: Arc<Intake>,
    metrics: Arc<Metrics>,
    router: Arc<dyn Router>,
    precisions: Arc<Vec<ReplicaPrecision>>,
    admission: Arc<Admission>,
    health: Arc<HealthBoard>,
    /// Partial-sum cache for §15 refinement escalations (shared by the
    /// pool; unused when `refine` is off or the backend has no planes).
    cache: Arc<PlaneCache>,
    /// [`PoolConfig::refine`] — gate on both ends of the hand-off.
    refine: bool,
}

impl WorkerCtx {
    fn clone_refs(&self) -> WorkerCtx {
        WorkerCtx {
            queues: Arc::clone(&self.queues),
            metrics: Arc::clone(&self.metrics),
            router: Arc::clone(&self.router),
            precisions: Arc::clone(&self.precisions),
            admission: Arc::clone(&self.admission),
            health: Arc::clone(&self.health),
            cache: Arc::clone(&self.cache),
            refine: self.refine,
        }
    }
}

/// Running server handle.
pub struct Server {
    queues: Arc<Intake>,
    /// Worker handles when supervision is off; with a supervisor, the
    /// handles live on the supervisor thread (it reaps and respawns
    /// them) and this stays empty.
    workers: Vec<JoinHandle<Result<()>>>,
    /// Shared metrics sink (read it live or via [`Server::snapshot`]).
    pub metrics: Arc<Metrics>,
    router: Arc<dyn Router>,
    precisions: Arc<Vec<ReplicaPrecision>>,
    admission: Arc<Admission>,
    health: Arc<HealthBoard>,
    /// §15 partial-sum cache behind refinement escalations; swept at
    /// shutdown so no partial outlives the pool.
    cache: Arc<PlaneCache>,
    /// Supervisor thread (DESIGN.md §13); `None` when supervision is
    /// disabled.
    supervisor: Option<JoinHandle<()>>,
    supervisor_stop: Arc<AtomicBool>,
    /// Highest precision floor in the pool; steal tags are clamped to it
    /// (a tag above every replica's floor would make items unstealable
    /// by replicas *equal* to the one allowed to serve them).
    max_floor: u32,
    started: Instant,
    img_elems: usize,
    batch: usize,
    /// The assembly size the delay projection divides queue depth by:
    /// the batching policy clamped to the smallest backend batch dim.
    assembly_batch: usize,
    queue_cap: usize,
    /// Background PI margin tuner ([`PoolConfig::escalation`]).
    tuner: Option<JoinHandle<()>>,
    tuner_stop: Arc<AtomicBool>,
}

impl Server {
    /// Start a PJRT-backed pool; compiles the fwd artifact on every
    /// replica before returning.  Convenience wrapper over
    /// [`Server::start_pool`] with a [`PjrtBackend`] factory (a
    /// homogeneous pool — for a heterogeneous PJRT pool, build
    /// per-replica `QuantConfig`s in a custom factory; precision is an
    /// *input* of the compiled graph, DESIGN.md §2, so one artifact
    /// serves every mix).
    pub fn start(manifest: &Manifest, cfg: ServerConfig) -> Result<Server> {
        let entry = manifest.model(&cfg.model)?;
        // reconcile the batching policy with the model's static batch
        // dim up front: a `Policy::default()` of 32 against a smaller
        // compiled batch used to slice out of bounds in the worker
        let policy = Policy {
            max_batch: cfg.policy.max_batch.clamp(1, entry.batch.max(1)),
            ..cfg.policy
        };
        // label the homogeneous pool with the qcfg's real bitwidths, not
        // the 8/8 placeholder: `Server::precisions` is documented as the
        // resolved pool precision, and the steal floors derive from it
        let precision = qcfg_precision(&cfg.qcfg);
        let factory = PjrtBackend::factory(
            manifest.clone(),
            cfg.model.clone(),
            cfg.qcfg.clone(),
            cfg.pallas,
        );
        Server::start_pool(
            PoolConfig {
                policy,
                queue_cap: cfg.queue_cap,
                replicas: cfg.replicas,
                precisions: vec![precision; cfg.replicas.max(1)],
                ..PoolConfig::default()
            },
            factory,
        )
    }

    /// Start `pool.replicas` workers over `factory`-built backends, each
    /// with its own bounded intake queue fronted by `pool.router`.
    /// Blocks until every replica reports ready; any replica's startup
    /// failure (backend construction error or panic) fails the whole
    /// start.
    pub fn start_pool(pool: PoolConfig, factory: BackendFactory) -> Result<Server> {
        ensure!(pool.replicas >= 1, "server needs at least one replica");
        ensure!(pool.queue_cap >= 1, "server needs a non-zero queue");
        let precisions: Vec<ReplicaPrecision> = if pool.precisions.is_empty() {
            vec![ReplicaPrecision::default(); pool.replicas]
        } else {
            ensure!(
                pool.precisions.len() == pool.replicas,
                "precision mix has {} entries for {} replicas",
                pool.precisions.len(),
                pool.replicas
            );
            pool.precisions.clone()
        };
        for p in &precisions {
            ensure!(p.wbits >= 1 && p.abits >= 1, "replica precision bits must be >= 1");
        }
        // admission + controller configs are validated before any worker
        // spawns, like every other config error path
        let admission =
            Arc::new(Admission::new(&pool.admission, pool.replicas, pool.queue_cap)?);
        if let Some(ctl) = &pool.escalation {
            ctl.validate()?;
            ensure!(
                pool.router.margin_knob().is_some(),
                "escalation budget needs a controller-tunable router \
                 (escalate:auto), got router '{}'",
                pool.router.name()
            );
        }
        if let Some(sup) = &pool.supervision {
            sup.validate()?;
        }
        let metrics = Arc::new(Metrics::new(pool.replicas));
        let floors: Vec<u32> = precisions.iter().map(|p| p.floor_bits()).collect();
        let queues = Arc::new(Intake::new(pool.queue_cap, floors, pool.work_stealing));
        let precisions = Arc::new(precisions);
        let health = Arc::new(HealthBoard::new(pool.replicas));
        // §15 partial-sum cache: every in-flight escalation holds a
        // queue slot, so queue_cap × replicas entries means no live
        // ticket is ever evicted under healthy operation — eviction
        // only fires when entries leak past their request (and the
        // stress oracle asserts they don't)
        let cache = Arc::new(PlaneCache::new(
            pool.queue_cap.saturating_mul(pool.replicas).max(1),
        ));
        let (ready_tx, ready_rx) =
            std::sync::mpsc::channel::<(usize, std::result::Result<Ready, String>)>();

        let policy = pool.policy;
        let mut workers = Vec::with_capacity(pool.replicas);
        for id in 0..pool.replicas {
            let ctx = WorkerCtx {
                queues: Arc::clone(&queues),
                metrics: Arc::clone(&metrics),
                router: Arc::clone(&pool.router),
                precisions: Arc::clone(&precisions),
                admission: Arc::clone(&admission),
                health: Arc::clone(&health),
                cache: Arc::clone(&cache),
                refine: pool.refine,
            };
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            // spawn-guard: replica_main registers a DeathWatch and wraps the factory + every forward in catch_unwind
            workers.push(std::thread::spawn(move || {
                replica_main(id, 0, ctx, policy, &factory, Some(ready))
            }));
        }
        drop(ready_tx);

        // readiness handshake: collect one report per replica; the
        // handshake channel closes early only if a worker died without
        // reporting (a panic outside the guarded factory call)
        let mut batch = usize::MAX;
        let mut img_elems: Option<usize> = None;
        let mut failures: Vec<String> = Vec::new();
        for _ in 0..pool.replicas {
            match ready_rx.recv() {
                Ok((id, Ok(r))) => {
                    batch = batch.min(r.batch);
                    match img_elems {
                        None => img_elems = Some(r.img_elems),
                        Some(e) if e != r.img_elems => failures.push(format!(
                            "replica {id}: backend img_elems {} disagrees with {e}",
                            r.img_elems
                        )),
                        Some(_) => {}
                    }
                }
                Ok((id, Err(msg))) => failures.push(format!("replica {id}: {msg}")),
                Err(_) => {
                    failures.push("a replica died before reporting readiness".into());
                    break;
                }
            }
        }
        if !failures.is_empty() || img_elems.is_none() {
            // close the intake and reap every worker before failing so
            // no thread outlives the failed start
            queues.close();
            for w in workers {
                let _ = w.join();
            }
            return Err(anyhow!("server start failed: {}", failures.join("; ")));
        }

        let max_floor = precisions.iter().map(|p| p.floor_bits()).max().unwrap_or(8);
        // the tuner starts only after every replica is ready, so its
        // first windows measure real traffic, not startup silence
        let tuner_stop = Arc::new(AtomicBool::new(false));
        let tuner = pool.escalation.as_ref().map(|ctl| {
            let ctl = ctl.clone();
            let knob = pool
                .router
                .margin_knob()
                // lint:allow(no-unwrap): start_pool returned Err above if the router has no knob; this re-read cannot fail
                .expect("checked before spawning workers");
            let metrics = Arc::clone(&metrics);
            let stop = Arc::clone(&tuner_stop);
            // spawn-guard: pure atomics loop, no client state; joined via tuner_stop on shutdown — a panic only stops margin tuning
            std::thread::spawn(move || run_margin_controller(ctl, knob, metrics, stop))
        });
        // with supervision on, the supervisor thread takes ownership of
        // the worker handles: it reaps deaths, respawns with backoff,
        // and joins the survivors at shutdown (DESIGN.md §13)
        let supervisor_stop = Arc::new(AtomicBool::new(false));
        let supervisor = pool.supervision.as_ref().map(|sup| {
            let sctx = SupervisorCtx {
                cfg: sup.clone(),
                ctx: WorkerCtx {
                    queues: Arc::clone(&queues),
                    metrics: Arc::clone(&metrics),
                    router: Arc::clone(&pool.router),
                    precisions: Arc::clone(&precisions),
                    admission: Arc::clone(&admission),
                    health: Arc::clone(&health),
                    cache: Arc::clone(&cache),
                    refine: pool.refine,
                },
                policy,
                factory: Arc::clone(&factory),
                stop: Arc::clone(&supervisor_stop),
            };
            let handles: Vec<Option<JoinHandle<Result<()>>>> =
                workers.drain(..).map(Some).collect();
            // spawn-guard: supervisor owns no client state; joined via supervisor_stop on shutdown, a panic degrades to the §9 no-supervision contract
            std::thread::spawn(move || supervisor_main(sctx, handles))
        });
        Ok(Server {
            queues,
            workers,
            metrics,
            router: pool.router,
            precisions,
            admission,
            health,
            cache,
            supervisor,
            supervisor_stop,
            max_floor,
            started: Instant::now(),
            // lint:allow(no-unwrap): the failures/is_none early-return above guarantees Some here
            img_elems: img_elems.unwrap(),
            batch,
            assembly_batch: policy.max_batch.clamp(1, batch),
            queue_cap: pool.queue_cap,
            tuner,
            tuner_stop,
        })
    }

    /// Blocking single-request inference (returns predicted class).
    pub fn infer(&self, image: Vec<f32>) -> Result<usize> {
        let rx = self.submit(image)?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped request"))?
            .map_err(|e| anyhow!(e))
    }

    /// Async submit; returns the response channel.  Rejects payloads of
    /// the wrong length before they enter a queue.
    pub fn submit(&self, image: Vec<f32>) -> Result<std::sync::mpsc::Receiver<Reply>> {
        if image.len() != self.img_elems {
            return Err(anyhow!("image must have {} elements", self.img_elems));
        }
        self.submit_unchecked(image)
    }

    /// Async submit without the payload-length precheck.  The worker
    /// validates defensively and answers `Err` for malformed payloads —
    /// it never zero-pads them into a fabricated class — so this is
    /// safe for callers that assemble [`Request`]s from untrusted
    /// sources (and for tests of exactly that path).
    pub fn submit_unchecked(&self, image: Vec<f32>)
                            -> Result<std::sync::mpsc::Receiver<Reply>> {
        let (rtx, rrx) = std::sync::mpsc::channel();
        // deterministic queue pick, skipping dead/retired replicas
        // (§13; with every replica healthy this is exactly `route`);
        // clamp defensively against custom routers returning
        // out-of-range shards
        let alive = |r: usize| self.health.alive(r);
        let shard =
            self.router.route_healthy(&self.precisions, &alive) % self.precisions.len();
        let mut item = Item::new(Request {
            payload: image,
            enqueued: Instant::now(),
            respond: rtx,
        });
        // clamp the steal tag to the pool's best floor: an unsatisfiable
        // AccuracyFloor routes everything to the most accurate replica,
        // and an unclamped tag would then gate its *equal-floor*
        // siblings out of stealing — silently serializing the pool
        item.min_bits = self.router.min_bits().min(self.max_floor);
        // gauge up BEFORE push: a replica may dequeue the item the
        // instant it lands, and its queue_pop must never observe the
        // gauge without this request counted (the pop saturates, so a
        // lost decrement would otherwise stick forever)
        self.metrics.queue_push();
        match self.queues.push(shard, item) {
            Ok(()) => {
                self.metrics.record_routed(shard);
                Ok(rrx)
            }
            Err(_) => {
                self.metrics.queue_pop(1);
                Err(anyhow!("server stopped"))
            }
        }
    }

    /// SLA-aware admission-controlled submit (DESIGN.md §12).  Routes
    /// like [`Server::submit`], then *refuses* instead of blocking:
    ///
    /// * [`Reject::DeadlineInfeasible`] when the projected queue delay
    ///   of the routed shard (depth off the load board × the replica's
    ///   estimated per-batch cost) already exceeds `opts.deadline`;
    /// * [`Reject::TenantThrottled`] when `opts.tenant` holds its fair
    ///   share of the shard's queue slots;
    /// * [`Reject::QueueFull`] when the shard is at capacity.
    ///
    /// Deadline-infeasible, tenant-throttled, and queue-full refusals
    /// count in `rejected`; an admitted request whose deadline expires
    /// while queued is answered `Err` at assembly and counted in
    /// `deadline_drops` — so every submission lands in exactly one of
    /// the four accounting buckets.  [`Reject::InvalidPayload`] and
    /// [`Reject::Closed`] mirror `submit`'s pre-admission errors and
    /// touch no counter.
    pub fn submit_with(&self, image: Vec<f32>, opts: SubmitOpts)
                       -> std::result::Result<std::sync::mpsc::Receiver<Reply>, Reject> {
        if image.len() != self.img_elems {
            return Err(Reject::InvalidPayload { got: image.len(), want: self.img_elems });
        }
        let alive = |r: usize| self.health.alive(r);
        let shard =
            self.router.route_healthy(&self.precisions, &alive) % self.precisions.len();
        let depth = self.queues.shard_len(shard);
        if let Some(d) = opts.deadline {
            let projected = self.admission.projected_delay(shard, depth, self.assembly_batch);
            if projected > d {
                self.metrics.record_rejected();
                return Err(Reject::DeadlineInfeasible { projected, deadline: d });
            }
        }
        if let Err((held, quota)) = self.admission.try_charge(shard, opts.tenant) {
            self.metrics.record_rejected();
            return Err(Reject::TenantThrottled { tenant: opts.tenant, shard, held, quota });
        }
        let (rtx, rrx) = std::sync::mpsc::channel();
        let mut item = Item::new(Request {
            payload: image,
            enqueued: Instant::now(),
            respond: rtx,
        });
        item.min_bits = self.router.min_bits().min(self.max_floor);
        // absolute deadline; a deadline too far out to represent is no
        // deadline at all
        item.deadline = opts.deadline.and_then(|d| Instant::now().checked_add(d));
        item.tenant = opts.tenant;
        item.tenant_shard = shard as u32;
        // gauge up BEFORE push, same as submit_unchecked
        self.metrics.queue_push();
        match self.queues.try_push(shard, item) {
            Ok(()) => {
                self.metrics.record_routed(shard);
                Ok(rrx)
            }
            Err(refused) => {
                self.metrics.queue_pop(1);
                self.admission.release(shard as u32, opts.tenant);
                match refused {
                    PushRefused::Full(_) => {
                        self.metrics.record_rejected();
                        Err(Reject::QueueFull { shard, depth, cap: self.queue_cap })
                    }
                    PushRefused::Closed(_) => Err(Reject::Closed),
                }
            }
        }
    }

    /// Runtime admission state (batch-cost estimates, tenant quotas).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// Replica health board (liveness states, heartbeat epochs,
    /// incarnations; DESIGN.md §13).
    pub fn health(&self) -> &HealthBoard {
        &self.health
    }

    /// §15 partial-sum cache behind refinement escalations.  Its
    /// `len()` is the number of in-flight refinement tickets; the
    /// stress oracle asserts it returns to 0 once the pool drains.
    pub fn plane_cache(&self) -> &PlaneCache {
        &self.cache
    }

    /// Fault history the supervisor already handled — deaths, watchdog
    /// trips, respawns, retirements.  These are operational events, not
    /// request failures, so they never fail [`Server::shutdown`];
    /// inspect this log to see how the pool self-healed.
    pub fn fault_log(&self) -> Vec<String> {
        self.health.fault_log()
    }

    /// Smallest static batch dim across replicas.
    pub fn max_batch(&self) -> usize {
        self.batch
    }

    /// Flattened elements per image, as reported by the replicas.
    pub fn img_elems(&self) -> usize {
        self.img_elems
    }

    /// Number of pool replicas.
    pub fn replicas(&self) -> usize {
        self.precisions.len()
    }

    /// Per-replica precision of the pool (resolved; never empty).
    pub fn precisions(&self) -> &[ReplicaPrecision] {
        &self.precisions
    }

    /// Stop accepting requests, drain every queue, join every replica,
    /// and return the final metrics — or the first worker error, which
    /// the pre-§9 server silently discarded.
    pub fn shutdown(mut self) -> Result<Snapshot> {
        self.queues.close();
        self.tuner_stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.tuner.take() {
            let _ = t.join();
        }
        // stop the supervisor *after* the close: it joins the current
        // workers (they exit once their queues drain) and routes their
        // outcomes to the fault log — deaths it already handled must
        // not fail a clean shutdown (DESIGN.md §13)
        self.supervisor_stop.store(true, Ordering::Relaxed);
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        let mut errs: Vec<String> = Vec::new();
        for (id, w) in self.workers.drain(..).enumerate() {
            match w.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => errs.push(format!("replica {id}: {e:#}")),
                Err(p) => errs.push(format!("replica {id} panicked: {}", payload_msg(&*p))),
            }
        }
        // final failover sweep: a pool that retired replicas mid-run
        // can strand items on closed shards (or lose its last popper
        // entirely) — every receiver must still resolve, so stranded
        // items get an `Err` reply and land in `failed_requests`
        let mut stranded = 0usize;
        for r in 0..self.precisions.len() {
            for it in self.queues.drain_shard(r) {
                self.admission.release(it.tenant_shard, it.tenant);
                let _ = it.req.respond.send(Err("server stopped before execution".into()));
                stranded += 1;
            }
        }
        if stranded > 0 {
            self.metrics.record_failed(stranded);
            self.metrics.queue_pop(stranded);
        }
        // the stranded items' refinement tickets (and any entry whose
        // request already resolved through a non-reclaiming path) die
        // with the pool — the cache must not outlive its requests
        self.cache.clear();
        let elapsed = self.started.elapsed().as_secs_f64();
        let snap = self.metrics.snapshot(elapsed);
        if errs.is_empty() {
            Ok(snap)
        } else {
            Err(anyhow!("server shutdown with worker errors: {}", errs.join("; ")))
        }
    }

    /// Metrics snapshot over the server's lifetime so far.
    pub fn snapshot(&self) -> Snapshot {
        self.metrics
            .snapshot(self.started.elapsed().as_secs_f64())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queues.close();
        self.tuner_stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.tuner.take() {
            let _ = t.join();
        }
        self.supervisor_stop.store(true, Ordering::Relaxed);
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The serving precision a whole-model [`QuantConfig`] amounts to: the
/// weakest *enabled* layer's bitwidths (a replica's accuracy floor is
/// its least precise quantized layer).  A fully-FP32 config reports
/// 32/32 — unquantized, above every floor.
fn qcfg_precision(qcfg: &QuantConfig) -> ReplicaPrecision {
    let mut p: Option<(u32, u32)> = None;
    for l in &qcfg.layers {
        if !l.w_en && !l.a_en {
            continue;
        }
        let w = if l.w_en { l.wbits.max(1) } else { 32 };
        let a = if l.a_en { l.abits.max(1) } else { 32 };
        p = Some(match p {
            None => (w, a),
            Some((pw, pa)) => (pw.min(w), pa.min(a)),
        });
    }
    match p {
        Some((w, a)) => ReplicaPrecision::new(w, a),
        None => ReplicaPrecision::new(32, 32),
    }
}

/// One replica thread: construct the backend (reporting the outcome
/// through the readiness handshake on first spawn — respawns skip it),
/// then assemble/execute from its own queue — stealing from sibling
/// tails when idle — until the intake closes and drains, the backend
/// fails permanently, or the watchdog supersedes this incarnation
/// (DESIGN.md §13).
fn replica_main(id: usize, incarnation: u64, ctx: WorkerCtx, policy: Policy,
                factory: &BackendFactory,
                ready: Option<Sender<(usize, std::result::Result<Ready, String>)>>)
                -> Result<()> {
    // armed for the whole thread life: every exit that is not the
    // clean queue-closed path — panic, fatal backend, startup failure
    // on respawn — reads as a death on the health board (§13)
    let mut watch = DeathWatch::new(Arc::clone(&ctx.health), id, incarnation);
    // the whole pre-report prelude (factory AND the geometry calls on
    // the fresh trait object) is guarded: a panic anywhere before the
    // handshake message would otherwise leave start_pool blocked on a
    // report that never comes
    let prelude = catch_unwind(AssertUnwindSafe(
        || -> Result<(Box<dyn InferenceBackend>, usize, usize)> {
            let backend = (**factory)(id)?;
            let batch = backend.batch().max(1);
            let img_elems = backend.img_elems();
            Ok((backend, batch, img_elems))
        },
    ));
    let (mut backend, batch, img_elems) = match prelude {
        Ok(Ok(t)) => t,
        Ok(Err(e)) => {
            let msg = format!("{e:#}");
            if let Some(ready) = &ready {
                let _ = ready.send((id, Err(msg.clone())));
            }
            return Err(anyhow!("backend startup failed: {msg}"));
        }
        Err(p) => {
            let msg = format!("backend startup panicked: {}", payload_msg(&*p));
            if let Some(ready) = &ready {
                let _ = ready.send((id, Err(msg.clone())));
            }
            return Err(anyhow!(msg));
        }
    };
    // per-replica clamp of the batching policy to this backend's static
    // batch dim (`Server::start` clamps from the manifest too; custom
    // factories get the same guarantee here)
    let policy = Policy { max_batch: policy.max_batch.clamp(1, batch), ..policy };
    if let Some(ready) = ready {
        let _ = ready.send((id, Ok(Ready { batch, img_elems })));
        // release the handshake channel NOW (the `ready` binding is
        // consumed here): holding it for the serving lifetime would
        // keep start_pool's recv() from ever seeing closure if a
        // sibling replica died without reporting
    }
    loop {
        // a superseded incarnation must not pop again: the watchdog
        // already handed this replica id to a replacement, and two
        // poppers on one shard would break the §11 contract.  The slot
        // belongs to the replacement now, so the death watch is moot.
        if !ctx.health.is_current(id, incarnation) {
            watch.disarm();
            return Err(anyhow!("replica {id} superseded by the watchdog"));
        }
        ctx.health.set_idle(id, incarnation);
        match ctx.queues.pop_batch(id, policy) {
            Assembled::Closed => {
                watch.disarm();
                return Ok(());
            }
            Assembled::Batch(mut items) => {
                ctx.health.set_busy(id, incarnation);
                ctx.metrics.queue_pop(items.len());
                // the tenant quota bounds *queue* occupancy: release the
                // slot the instant the item leaves the queue, and blank
                // the tag so an escalation re-push can't release twice
                for it in items.iter_mut() {
                    ctx.admission.release(it.tenant_shard, it.tenant);
                    it.tenant_shard = Item::<Payload, Reply>::TENANT_UNCHARGED;
                }
                let stolen = items.iter().filter(|i| i.stolen).count();
                if stolen > 0 {
                    ctx.metrics.record_stolen(id, stolen);
                }
                execute_assembly(backend.as_mut(), id, incarnation, items, &ctx);
                // a permanently failed backend exits *between* batches:
                // every item popped above already got its reply, so the
                // §12 buckets stay exact through the death, and the
                // armed watch marks the slot dead for the supervisor
                if backend.fatal() {
                    return Err(anyhow!("replica {id}: backend failed permanently"));
                }
            }
        }
    }
}

/// Execute one assembled batch on a backend: validate payloads, split
/// oversized assemblies, pad, forward, argmax(+margin), escalate or
/// reply.  Infallible by construction — every item either gets exactly
/// one reply here or is re-enqueued exactly once on the accurate tier
/// (which always replies: escalated items never re-escalate), and
/// backend errors/panics are converted into error replies, never worker
/// death.  Escalated items carrying a live §15 cache ticket are served
/// by *refinement* — residual planes added to the cached partial sums —
/// and every terminal path reclaims the ticket, so cache entries never
/// outlive their request.
fn execute_assembly(backend: &mut dyn InferenceBackend, id: usize, incarnation: u64,
                    items: Vec<Item<Payload, Reply>>, ctx: &WorkerCtx) {
    let batch = backend.batch().max(1);
    let img_elems = backend.img_elems();
    // an item whose SLA deadline expired while queued is dropped with
    // an `Err` reply — executing it would spend a batch slot on an
    // answer the client has already abandoned (DESIGN.md §12)
    let now = Instant::now();
    let (items, expired): (Vec<_>, Vec<_>) = items
        .into_iter()
        .partition(|it| !it.deadline.map_or(false, |d| now >= d));
    if !expired.is_empty() {
        let n = expired.len();
        for it in expired {
            reclaim_ticket(&it, ctx);
            let _ = it.req.respond.send(Err(format!(
                "deadline exceeded before execution ({:.1}ms in queue)",
                it.req.enqueued.elapsed().as_secs_f64() * 1e3
            )));
        }
        ctx.metrics.record_deadline_drops(id, n);
    }
    // an item whose payload length is wrong gets an Err reply; it is
    // never zero-padded and answered with a fabricated class (submit
    // validates, but `Request` is public and the batcher is reusable)
    let (mut valid, invalid): (Vec<_>, Vec<_>) = items
        .into_iter()
        .partition(|it| it.req.payload.len() == img_elems);
    for it in invalid {
        reclaim_ticket(&it, ctx);
        let _ = it.req.respond.send(Err(format!(
            "payload has {} elements, model wants {img_elems}",
            it.req.payload.len()
        )));
        ctx.metrics.record_rejected();
    }
    // §15 refinement partition: escalated items whose partial-sum cache
    // entry is still live, from a still-current incarnation, and shaped
    // for this model skip the full re-run — only their residual planes
    // execute.  Anything else (ticket evicted, source replica respawned
    // since the first pass, refinement off, non-plane backend) falls
    // back to the pre-§15 full re-run below, which always works.
    let mut refinable: Vec<(Item<Payload, Reply>, PlanePartial)> = Vec::new();
    {
        // every arriving ticket is consumed HERE, refinable or not — a
        // ticketed item that lands on a non-plane replica of a mixed
        // pool must not strand its cache entry
        let refines = ctx.refine && backend.planes() > 0;
        let mut rerun = Vec::with_capacity(valid.len());
        for mut it in valid {
            let rid = std::mem::take(&mut it.refine_id);
            let entry = if rid != 0 { ctx.cache.take(rid) } else { None };
            match entry {
                Some(e)
                    if refines
                        && ctx.health.is_current(e.source, e.incarnation)
                        && e.partial.a_int.len() == img_elems =>
                {
                    refinable.push((it, e.partial));
                }
                _ => rerun.push(it),
            }
        }
        valid = rerun;
    }
    while !refinable.is_empty() {
        let take = batch.min(refinable.len());
        let group: Vec<(Item<Payload, Reply>, PlanePartial)> =
            refinable.drain(..take).collect();
        let t0 = Instant::now();
        let n = group.len();
        let parts: Vec<PlanePartial> = group.iter().map(|(_, p)| p.clone()).collect();
        let total = backend.planes().max(1);
        let residual = parts
            .iter()
            .map(|p| total.saturating_sub(p.bits))
            .max()
            .unwrap_or(0);
        let out = match catch_unwind(AssertUnwindSafe(|| backend.refine(&parts))) {
            Ok(Some(r)) => r,
            Ok(None) => Err(anyhow!(
                "backend advertises {total} planes but does not refine"
            )),
            Err(p) => Err(anyhow!("backend panicked: {}", payload_msg(&*p))),
        }
        .and_then(|logits| {
            ensure!(
                logits.rank() == 2 && logits.shape[0] >= n,
                "backend returned logits shaped {:?} for a {n}-partial refinement",
                logits.shape
            );
            Ok(logits)
        });
        let dt = t0.elapsed().as_secs_f64();
        match out {
            Ok(logits) => {
                // a refinement batch runs `residual` of `total` planes:
                // scale the observation to its full-batch equivalent so
                // the §12 delay projection stays honest
                ctx.admission
                    .observe_partial_batch_cost(id, dt, residual as f64 / total as f64);
                let preds = logits.argmax_margin_rows();
                for (i, (it, _)) in group.into_iter().enumerate() {
                    // refined items are already escalated: they reply
                    // here unconditionally, never re-escalate
                    let _ = it.req.respond.send(Ok(preds[i].0));
                }
                ctx.metrics.record_refined(id, n);
                ctx.metrics.record_batch_answered(id, n, n, dt, batch.saturating_sub(n));
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for (it, _) in &group {
                    let _ = it.req.respond.send(Err(msg.clone()));
                }
                ctx.metrics.record_error(id, n, dt);
            }
        }
        // heartbeat per refinement group, same contract as the chunk
        // loop below: the watchdog deadline bounds one group
        ctx.health.beat(id);
    }
    // defensive split: an assembly larger than the backend's static
    // batch dim (mis-clamped policy, future policy bugs) is executed in
    // chunks instead of slicing `xdata` out of bounds
    while !valid.is_empty() {
        let take = batch.min(valid.len());
        let chunk: Vec<Item<Payload, Reply>> = valid.drain(..take).collect();
        let t0 = Instant::now();
        let n = chunk.len();
        // pad to the static batch dim
        let mut xdata = vec![0.0f32; batch * img_elems];
        for (i, it) in chunk.iter().enumerate() {
            xdata[i * img_elems..(i + 1) * img_elems].copy_from_slice(&it.req.payload);
        }
        let out = Tensor::new(vec![batch, img_elems], xdata)
            .and_then(|x| {
                // a backend panic fails the chunk, not the replica: the
                // queued clients behind it must still be answered
                match catch_unwind(AssertUnwindSafe(|| backend.forward(x))) {
                    Ok(r) => r,
                    Err(p) => Err(anyhow!("backend panicked: {}", payload_msg(&*p))),
                }
            })
            .and_then(|logits| {
                ensure!(
                    logits.rank() == 2 && logits.shape[0] >= n,
                    "backend returned logits shaped {:?} for a {n}-request chunk",
                    logits.shape
                );
                Ok(logits)
            });
        let dt = t0.elapsed().as_secs_f64();
        // first-run decisions in this chunk: the denominator of the
        // escalation rate the §12 PI controller steers
        let firsts = chunk.iter().filter(|it| !it.escalated).count();
        match out {
            Ok(logits) => {
                ctx.admission.observe_batch_cost(id, dt);
                if firsts > 0 {
                    ctx.metrics.record_first_decisions(firsts);
                }
                let preds = logits.argmax_margin_rows();
                // §15: the bitplane partial sums behind this chunk's
                // logits, one per row — taken whether or not anything
                // escalates, so the backend never accumulates state
                let partials = if ctx.refine && backend.planes() > 0 {
                    backend.take_partials()
                } else {
                    None
                };
                let mut answered = 0usize;
                let mut escalated = 0usize;
                let mut failovers = 0usize;
                for (i, it) in chunk.into_iter().enumerate() {
                    let (pred, margin) = preds[i];
                    // escalate at most once per request, and only ever
                    // strictly *up* in precision — escalated items never
                    // re-escalate, so the hand-off chain is acyclic and
                    // always drains (DESIGN.md §10)
                    let want = match it.escalated {
                        true => None,
                        false => ctx.router.escalate(id, margin, &ctx.precisions),
                    }
                    .filter(|&t| {
                        t != id
                            && t < ctx.precisions.len()
                            && ctx.precisions[t].floor_bits()
                                > ctx.precisions[id].floor_bits()
                    });
                    match want {
                        Some(want) => {
                            let mut it = it;
                            it.escalated = true;
                            it.stolen = false;
                            // §15: park this row's partial sums in the
                            // cache so the receiving replica can refine
                            // instead of re-running; keyed to OUR
                            // incarnation so a respawn fences off any
                            // partials its dead predecessor produced
                            if let Some(p) =
                                partials.as_ref().and_then(|ps| ps.get(i))
                            {
                                it.refine_id =
                                    ctx.cache.insert(id, incarnation, p.clone());
                            }
                            // fall down the ladder of *live* higher-
                            // precision replicas, most accurate first,
                            // with a bounded wait per rung: a dead or
                            // saturated accurate replica must not
                            // blackhole the request (DESIGN.md §13).
                            // When the ladder is exhausted the low-
                            // confidence fast answer stands — it beats
                            // a dropped request.
                            let alive = |t: usize| ctx.health.alive(t);
                            let mut ladder =
                                escalation_ladder(id, &ctx.precisions, &alive);
                            if it.refine_id != 0 {
                                // a ticketed item refines to full plane
                                // depth on ANY replica, so when every
                                // strictly-higher rung is dead or full
                                // the rest of the live pool (highest
                                // floor first) beats answering with the
                                // low-confidence fast result
                                let mut extras: Vec<usize> = (0..ctx
                                    .precisions
                                    .len())
                                    .filter(|&t| {
                                        t != id
                                            && alive(t)
                                            && !ladder.contains(&t)
                                    })
                                    .collect();
                                extras.sort_by_key(|&t| {
                                    std::cmp::Reverse(
                                        ctx.precisions[t].floor_bits(),
                                    )
                                });
                                ladder.extend(extras);
                            }
                            let mut holding = Some(it);
                            let mut landed: Option<usize> = None;
                            for t in ladder {
                                // the ladder loop owns the item between
                                // attempts: refused pushes hand it back,
                                // a landed push breaks — so the slot is
                                // always occupied at loop top
                                let Some(mut item) = holding.take() else { break };
                                item.min_bits = ctx.precisions[t].floor_bits();
                                ctx.metrics.queue_push();
                                match ctx.queues.push_timeout(
                                    t,
                                    item,
                                    FAILOVER_PUSH_WAIT,
                                ) {
                                    Ok(()) => {
                                        landed = Some(t);
                                        break;
                                    }
                                    Err(PushRefused::Full(b))
                                    | Err(PushRefused::Closed(b)) => {
                                        ctx.metrics.queue_pop(1);
                                        holding = Some(b);
                                    }
                                }
                            }
                            match landed {
                                Some(t) => {
                                    escalated += 1;
                                    if t != want {
                                        failovers += 1;
                                    }
                                }
                                None => {
                                    // lint:allow(no-unwrap): landed == None means no rung accepted the item, so every attempt handed it back
                                    let it = holding.expect("held item");
                                    // the ticket dies with the hand-off:
                                    // nobody will ever refine this item
                                    reclaim_ticket(&it, ctx);
                                    let _ = it.req.respond.send(Ok(pred));
                                    answered += 1;
                                    failovers += 1;
                                }
                            }
                        }
                        None => {
                            let _ = it.req.respond.send(Ok(pred));
                            answered += 1;
                        }
                    }
                }
                if escalated > 0 {
                    ctx.metrics.record_escalated(id, escalated);
                }
                if failovers > 0 {
                    ctx.metrics.record_failovers(failovers);
                }
                ctx.metrics.record_batch_answered(id, n, answered, dt, batch - n);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for it in &chunk {
                    let _ = it.req.respond.send(Err(msg.clone()));
                }
                // failed batches are accounted too: the error counters
                // + their wall time (escalated items in a failed chunk
                // get their one reply here, as an Err)
                ctx.metrics.record_error(id, n, dt);
            }
        }
        // heartbeat: one chunk of progress (even a failed one — the
        // replica is alive, its backend merely errored).  Refreshes the
        // busy stamp so the watchdog deadline bounds one *chunk*, not a
        // whole multi-chunk assembly (DESIGN.md §13).
        ctx.health.beat(id);
    }
}

/// Drop `it`'s §15 partial-sum cache entry, if it holds one.  Called on
/// every terminal path that will never refine — expiry, invalid
/// payload, exhausted escalation ladder, failed re-home, pool shutdown
/// — so tickets cannot outlive their request (the stress oracle's
/// no-leak invariant).
fn reclaim_ticket(it: &Item<Payload, Reply>, ctx: &WorkerCtx) {
    if it.refine_id != 0 {
        let _ = ctx.cache.take(it.refine_id);
    }
}

/// Everything the supervisor thread needs (DESIGN.md §13).
struct SupervisorCtx {
    cfg: SupervisionCfg,
    ctx: WorkerCtx,
    policy: Policy,
    factory: BackendFactory,
    stop: Arc<AtomicBool>,
}

/// Supervisor loop (DESIGN.md §13): every `heartbeat` tick, inspect the
/// health board.
///
/// * A **dead** replica (death-watch report: panic, fatal backend,
///   failed respawn) is reaped — its handle joined, the outcome logged
///   to the fault history — and a respawn is scheduled after a capped
///   exponential backoff.  The restart budget is a per-replica
///   *lifetime* budget: a flapping backend burns through it and is
///   retired rather than respawned forever.
/// * A **busy** replica whose progress stamp went stale past the
///   watchdog deadline is wedged inside `forward`: its incarnation is
///   superseded (the zombie observes this at its next loop-top and
///   exits; its handle is abandoned, never joined — joining a wedged
///   thread would wedge the supervisor too) and it takes the dead path
///   on the next tick.
/// * A replica over its restart budget is **retired**: its shard is
///   closed and drained, and the drained items are re-homed onto live
///   floor-compatible shards ([`rehome_items`]).  The pool runs
///   degraded on the survivors.
///
/// Each tick also refreshes the admission layer's healthy-replica
/// count so the §12 delay projection stops promising dead capacity.
/// On `stop`, the remaining handles are joined and their outcomes go
/// to the fault log — supervised deaths never fail `shutdown`.
fn supervisor_main(sup: SupervisorCtx, mut handles: Vec<Option<JoinHandle<Result<()>>>>) {
    let n = sup.ctx.precisions.len();
    let mut attempts = vec![0u32; n];
    let mut respawn_at: Vec<Option<Instant>> = vec![None; n];
    while !sup.stop.load(Ordering::Relaxed) {
        std::thread::sleep(sup.cfg.heartbeat);
        for r in 0..n {
            match sup.ctx.health.state(r) {
                ReplicaState::Retired => continue,
                ReplicaState::Dead if respawn_at[r].is_none() => {
                    // reap the exited worker (death-watch reports fire
                    // as the thread unwinds, so this join is prompt);
                    // a watchdog-superseded zombie left no handle
                    if let Some(h) = handles[r].take() {
                        let outcome = match h.join() {
                            Ok(Ok(())) => format!("replica {r}: worker exited"),
                            Ok(Err(e)) => format!("replica {r}: worker died: {e:#}"),
                            Err(p) => format!(
                                "replica {r}: worker panicked: {}",
                                payload_msg(&*p)
                            ),
                        };
                        sup.ctx.health.log_fault(outcome);
                    }
                    attempts[r] += 1;
                    if attempts[r] > sup.cfg.max_restarts {
                        retire_replica(r, &sup);
                    } else {
                        let delay = sup.cfg.backoff_for(attempts[r]);
                        sup.ctx.health.log_fault(format!(
                            "replica {r}: respawn attempt {}/{} in {delay:?}",
                            attempts[r], sup.cfg.max_restarts
                        ));
                        respawn_at[r] = Instant::now().checked_add(delay);
                    }
                }
                ReplicaState::Busy if sup.ctx.health.stale_busy(r, sup.cfg.watchdog) => {
                    // wedged inside forward: invalidate the incarnation
                    // (the zombie exits at its next loop-top, §11
                    // one-popper contract intact) and abandon its
                    // handle.  The dead arm schedules the respawn on
                    // the next tick.
                    sup.ctx.health.supersede(r);
                    drop(handles[r].take());
                    sup.ctx.health.log_fault(format!(
                        "replica {r}: watchdog tripped (no progress in {:?}), superseded",
                        sup.cfg.watchdog
                    ));
                }
                _ => {}
            }
            if let Some(at) = respawn_at[r] {
                if Instant::now() >= at && !sup.stop.load(Ordering::Relaxed) {
                    respawn_at[r] = None;
                    // fresh incarnation: any still-unwinding remnant of
                    // the old worker is fenced off the health board and
                    // the shard.  The EWMA its dead incarnation left —
                    // possibly poisoned by jitter or a hang — is reset
                    // to the constructor seed.
                    let inc = sup.ctx.health.supersede(r);
                    sup.ctx.admission.reseed_cost(r);
                    let wctx = sup.ctx.clone_refs();
                    let factory = Arc::clone(&sup.factory);
                    let policy = sup.policy;
                    // spawn-guard: replica_main registers a DeathWatch and wraps the factory + every forward in catch_unwind
                    handles[r] = Some(std::thread::spawn(move || {
                        replica_main(r, inc, wctx, policy, &factory, None)
                    }));
                    sup.ctx.metrics.record_restart(r);
                    sup.ctx.health.log_fault(format!(
                        "replica {r}: respawned (incarnation {inc})"
                    ));
                }
            }
        }
        sup.ctx
            .admission
            .set_healthy_replicas(sup.ctx.health.alive_count());
    }
    // shutdown: the intake is already closed; join the survivors and
    // route their outcomes to the fault log (worker errors the
    // supervisor owns must not fail a clean shutdown)
    for (r, h) in handles.into_iter().enumerate() {
        let Some(h) = h else { continue };
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => sup.ctx.health.log_fault(format!("replica {r}: {e:#}")),
            Err(p) => sup
                .ctx
                .health
                .log_fault(format!("replica {r} panicked: {}", payload_msg(&*p))),
        }
    }
}

/// Retire `r` permanently (restart budget exhausted): mark it on the
/// health board, close its shard so routing/steal traffic stops, and
/// re-home the backlog onto live shards (DESIGN.md §13).
fn retire_replica(r: usize, sup: &SupervisorCtx) {
    sup.ctx.health.retire(r);
    sup.ctx.metrics.record_retired();
    sup.ctx.health.log_fault(format!(
        "replica {r}: restart budget ({}) exhausted, retired; pool degraded to {} replicas",
        sup.cfg.max_restarts,
        sup.ctx.health.alive_count()
    ));
    sup.ctx.queues.close_shard(r);
    let items = sup.ctx.queues.drain_shard(r);
    if !items.is_empty() {
        rehome_items(r, items, &sup.ctx);
    }
}

/// Failover drain: push each item stranded on dead shard `from` onto a
/// live shard whose precision floor honors the item's `min_bits` tag,
/// least-loaded first.  An unsatisfiable tag is clamped to the best
/// live floor (a degraded answer beats none — same clamp `route`
/// applies); with nothing alive at all the item is answered `Err` and
/// counted in `failed_requests`, so every receiver still resolves.
fn rehome_items(from: usize, items: Vec<Item<Payload, Reply>>, ctx: &WorkerCtx) {
    let mut requeued = 0usize;
    let mut failed = 0usize;
    for mut it in items {
        // the queue-slot charge does not follow the item to its new
        // shard: release it here and blank the tag, exactly like a pop
        ctx.admission.release(it.tenant_shard, it.tenant);
        it.tenant_shard = Item::<Payload, Reply>::TENANT_UNCHARGED;
        it.stolen = false;
        let mut targets: Vec<usize> = (0..ctx.precisions.len())
            .filter(|&t| {
                t != from
                    && ctx.health.alive(t)
                    && ctx.precisions[t].floor_bits() >= it.min_bits
            })
            .collect();
        if targets.is_empty() {
            if let Some(best) = (0..ctx.precisions.len())
                .filter(|&t| t != from && ctx.health.alive(t))
                .map(|t| ctx.precisions[t].floor_bits())
                .max()
            {
                it.min_bits = it.min_bits.min(best);
                targets = (0..ctx.precisions.len())
                    .filter(|&t| {
                        t != from
                            && ctx.health.alive(t)
                            && ctx.precisions[t].floor_bits() >= it.min_bits
                    })
                    .collect();
            }
        }
        targets.sort_by_key(|&t| ctx.queues.shard_len(t));
        let mut holding = Some(it);
        for t in targets {
            // same slot discipline as the escalation ladder: refused
            // pushes hand the item back, a landed push breaks
            let Some(item) = holding.take() else { break };
            match ctx.queues.push_timeout(t, item, FAILOVER_PUSH_WAIT) {
                Ok(()) => {
                    requeued += 1;
                    break;
                }
                Err(PushRefused::Full(b)) | Err(PushRefused::Closed(b)) => holding = Some(b),
            }
        }
        if let Some(it) = holding {
            reclaim_ticket(&it, ctx);
            let _ = it.req.respond.send(Err(format!(
                "replica {from} retired and no live replica can serve this request"
            )));
            failed += 1;
        }
    }
    if requeued > 0 {
        ctx.metrics.record_drained_requeues(requeued);
    }
    if failed > 0 {
        // these items left the queue for good: failed bucket + gauge
        ctx.metrics.record_failed(failed);
        ctx.metrics.queue_pop(failed);
    }
    ctx.health.log_fault(format!(
        "replica {from}: drained shard re-homed {requeued} items, failed {failed}"
    ));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Format;

    #[test]
    fn qcfg_precision_reports_the_weakest_enabled_layer() {
        let q = QuantConfig::uniform(3, Format::DyBit, 4, 8);
        assert_eq!(qcfg_precision(&q), ReplicaPrecision::new(4, 8));
        // FP32 (all layers disabled) is unquantized: above every floor
        let fp = QuantConfig::fp32(2);
        assert_eq!(qcfg_precision(&fp), ReplicaPrecision::new(32, 32));
        // mixed per-layer assignment floors at the weakest layer
        let mut q = QuantConfig::uniform(3, Format::DyBit, 8, 8);
        q.layers[1].wbits = 2;
        q.layers[2].abits = 4;
        assert_eq!(qcfg_precision(&q), ReplicaPrecision::new(2, 4));
    }

    /// Satellite of the §11 PR: the config error paths must reject with
    /// descriptive `Err`s before any worker spawns, never panic.
    #[test]
    fn start_pool_rejects_bad_configs_descriptively() {
        use super::super::{SimBackend, SimBackendCfg};

        let factory = || SimBackend::factory(SimBackendCfg::tiny(1));
        // mix length ≠ replicas
        let pool = PoolConfig {
            replicas: 3,
            precisions: vec![ReplicaPrecision::uniform(4); 2],
            ..PoolConfig::default()
        };
        let e = Server::start_pool(pool, factory()).unwrap_err().to_string();
        assert!(e.contains("2 entries") && e.contains("3 replicas"), "{e}");
        // zero-bit precision entry
        let pool = PoolConfig {
            replicas: 1,
            precisions: vec![ReplicaPrecision::new(0, 8)],
            ..PoolConfig::default()
        };
        let e = Server::start_pool(pool, factory()).unwrap_err().to_string();
        assert!(e.contains(">= 1"), "{e}");
        // zero replicas / zero queue
        let e = Server::start_pool(PoolConfig { replicas: 0, ..PoolConfig::default() },
                                   factory())
            .unwrap_err()
            .to_string();
        assert!(e.contains("replica"), "{e}");
        let e = Server::start_pool(PoolConfig { queue_cap: 0, ..PoolConfig::default() },
                                   factory())
            .unwrap_err()
            .to_string();
        assert!(e.contains("queue"), "{e}");
        // §12 satellites: bad admission / controller configs fail the
        // start the same way, before any worker spawns
        let pool = PoolConfig {
            admission: AdmissionCfg { slack: -1.0, ..AdmissionCfg::default() },
            ..PoolConfig::default()
        };
        let e = Server::start_pool(pool, factory()).unwrap_err().to_string();
        assert!(e.contains("slack"), "{e}");
        // an escalation budget without a tunable router is a config
        // error, not a silently dead controller
        let pool = PoolConfig {
            escalation: Some(EscalationController::with_budget(0.25)),
            ..PoolConfig::default()
        };
        let e = Server::start_pool(pool, factory()).unwrap_err().to_string();
        assert!(e.contains("escalate:auto"), "{e}");
        // inf margin bounds are rejected by the controller validation
        let mut ctl = EscalationController::with_budget(0.25);
        ctl.bounds = (0.0, f32::INFINITY);
        let pool = PoolConfig {
            router: Arc::new(super::super::Escalate::auto_tuned()),
            escalation: Some(ctl),
            ..PoolConfig::default()
        };
        let e = Server::start_pool(pool, factory()).unwrap_err().to_string();
        assert!(e.contains("finite"), "{e}");
        // §13 satellite: a bad supervision config fails the start
        // before any worker spawns, like every other config error
        let pool = PoolConfig {
            supervision: Some(SupervisionCfg {
                watchdog: Duration::from_millis(1),
                ..SupervisionCfg::default()
            }),
            ..PoolConfig::default()
        };
        let e = Server::start_pool(pool, factory()).unwrap_err().to_string();
        assert!(e.contains("watchdog"), "{e}");
    }
}

/// Closed-loop load generator: `clients` threads each issue `per_client`
/// sequential requests of synthetic images; returns the final snapshot.
pub fn load_test(server: &Server, clients: usize, per_client: usize,
                 img_elems: usize) -> Result<()> {
    let _ = server.metrics.requests.load(Ordering::Relaxed);
    std::thread::scope(|scope| {
        for c in 0..clients {
            scope.spawn(move || {
                let mut rng = crate::util::rng::Rng::new(100 + c as u64);
                for _ in 0..per_client {
                    let img = rng.normal_vec(img_elems);
                    if let Ok(rx) = server.submit(img) {
                        let _ = rx.recv_timeout(Duration::from_secs(120));
                    }
                }
            });
        }
    });
    Ok(())
}

/// Options for [`load_test_opts`]: the admission-controlled load
/// generator's SLA and tenant spread.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadOpts {
    /// Per-request deadline passed through to [`Server::submit_with`].
    pub deadline: Option<Duration>,
    /// Tenant ids are spread over `max(tenants, 1)` buckets by client
    /// index.
    pub tenants: u32,
}

/// What [`load_test_opts`] observed at the submit boundary.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadReport {
    /// Requests admitted (each then waited for its reply).
    pub accepted: usize,
    /// Requests refused by admission with a typed [`Reject`].
    pub rejected: usize,
}

/// Closed-loop load generator over [`Server::submit_with`]: like
/// [`load_test`], but every request carries `opts` and admission
/// refusals are counted instead of blocking.
pub fn load_test_opts(server: &Server, clients: usize, per_client: usize,
                      img_elems: usize, opts: LoadOpts) -> Result<LoadReport> {
    use std::sync::atomic::AtomicUsize;
    let accepted = AtomicUsize::new(0);
    // named `refused`, not `rejected`: the four-bucket accounting name
    // is reserved for Metrics recorder methods (DESIGN.md §12/§14)
    let refused = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for c in 0..clients {
            let (accepted, refused) = (&accepted, &refused);
            scope.spawn(move || {
                let mut rng = crate::util::rng::Rng::new(100 + c as u64);
                let sopts = SubmitOpts {
                    deadline: opts.deadline,
                    tenant: c as u32 % opts.tenants.max(1),
                };
                for _ in 0..per_client {
                    let img = rng.normal_vec(img_elems);
                    match server.submit_with(img, sopts) {
                        Ok(rx) => {
                            accepted.fetch_add(1, Ordering::Relaxed);
                            let _ = rx.recv_timeout(Duration::from_secs(120));
                        }
                        Err(_) => {
                            refused.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    Ok(LoadReport {
        accepted: accepted.load(Ordering::Relaxed),
        rejected: refused.load(Ordering::Relaxed),
    })
}
