//! Overload-safe admission control + closed-loop escalation tuning
//! (DESIGN.md §12).
//!
//! Under overload a blocking intake degrades the worst way possible:
//! every client waits, queue delay grows without bound, and by the
//! time a reply arrives its SLA is long gone — throughput stays high
//! while *goodput* (on-time answers) collapses.  This module makes the
//! pool refuse work it cannot serve in time, at the only moment that
//! is cheap: submit.
//!
//! Three cooperating mechanisms:
//!
//! * **SLA-aware admission.**  `Server::submit_with` carries an
//!   optional relative deadline.  Admission projects the queue delay
//!   of the routed shard as
//!   `(⌊depth/max_batch⌋ + 1) · ĉ_r · slack`
//!   where `depth` comes off the §11 load board ([`shard_len`]) and
//!   `ĉ_r` is the per-batch cost estimate for that replica's precision
//!   — seeded from the §3 cycle model like the §7 cost table
//!   (`SimBackendCfg::projected_batch_costs`), then refined online by
//!   an EWMA over observed batch wall times.  An infeasible request is
//!   rejected immediately with a typed reason instead of blocking;
//!   an admitted request that still expires in the queue is dropped at
//!   assembly with an `Err` reply and counted in `deadline_drops` —
//!   every submission resolves exactly once:
//!   `requests + failed_requests + rejected + deadline_drops ==
//!   submitted`.
//!
//! * **Per-tenant fair queuing.**  Each shard's capacity is split into
//!   per-tenant occupancy quotas (`⌈cap/tenants⌉` slots): a tenant at
//!   its quota on a shard is throttled with
//!   [`Reject::TenantThrottled`] while other tenants keep landing —
//!   one hot tenant can fill at most its share of every queue, never
//!   the pool.  Occupancy is charged at submit and released when the
//!   item leaves the queue, so the quota bounds *queue depth*, not
//!   throughput: a lone tenant on an idle pool still runs at full
//!   speed (work-conserving).  The occupancy table is a flat array of
//!   atomics — no lock is held with any intake lock, so the §11
//!   `shard → board` order is untouched.
//!
//! * **Closed-loop margin tuning.**  The Fig. 6 accuracy/latency
//!   operating point becomes a feedback loop: `escalate:auto` exposes
//!   its margin as a shared [`MarginKnob`] and a background PI
//!   controller ([`EscalationController`]) steers the observed
//!   escalation rate (Δ`escalations` / Δ`first_runs` per window, a
//!   sliding window over the `Metrics` counters) onto a configured
//!   budget.  Velocity form — `m += kp·Δerr + ki·err·dt` — so the
//!   clamp to `bounds` doubles as anti-windup.
//!
//! [`shard_len`]: super::batcher::IntakeQueue::shard_len

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Result};

use super::metrics::Metrics;
use super::router::MarginKnob;

/// Per-request options for `Server::submit_with` (DESIGN.md §12).
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOpts {
    /// Relative SLA deadline: reject at submit when the projected
    /// queue delay already exceeds it; drop (with an `Err` reply) at
    /// assembly when it expires in the queue.  `None` = no SLA.
    pub deadline: Option<Duration>,
    /// Tenant id for fair queuing (`0` = the default tenant).  Mapped
    /// onto `AdmissionCfg::tenants` buckets by modulo.
    pub tenant: u32,
}

impl SubmitOpts {
    /// Deadline-only options for the common single-tenant case.
    pub fn with_deadline(deadline: Duration) -> Self {
        SubmitOpts { deadline: Some(deadline), tenant: 0 }
    }
}

/// Typed admission refusal: why `submit_with` did not enqueue.  Every
/// variant is returned *before* a reply channel exists, so no client
/// is ever left holding a dead `Receiver`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reject {
    /// The routed shard is at capacity — a deadline-less `submit`
    /// would have blocked here; admission refuses instead.
    QueueFull { shard: usize, depth: usize, cap: usize },
    /// The projected queue delay already exceeds the request's
    /// deadline; executing it would only burn capacity on a reply the
    /// client will discard.
    DeadlineInfeasible { projected: Duration, deadline: Duration },
    /// The tenant already holds its fair share of the routed shard's
    /// queue slots.
    TenantThrottled { tenant: u32, shard: usize, held: usize, quota: usize },
    /// Payload length mismatch (checked before routing, mirrors
    /// `submit`'s length error).
    InvalidPayload { got: usize, want: usize },
    /// The server stopped (mirrors `submit`'s "server stopped").
    Closed,
}

impl std::fmt::Display for Reject {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reject::QueueFull { shard, depth, cap } => {
                write!(f, "queue full: shard {shard} at {depth}/{cap}")
            }
            Reject::DeadlineInfeasible { projected, deadline } => write!(
                f,
                "deadline infeasible: projected queue delay {:.3}ms exceeds deadline {:.3}ms",
                projected.as_secs_f64() * 1e3,
                deadline.as_secs_f64() * 1e3
            ),
            Reject::TenantThrottled { tenant, shard, held, quota } => write!(
                f,
                "tenant {tenant} throttled: holds {held}/{quota} slots of shard {shard}"
            ),
            Reject::InvalidPayload { got, want } => {
                write!(f, "invalid payload: {got} elements, image needs {want}")
            }
            Reject::Closed => write!(f, "server stopped"),
        }
    }
}

impl std::error::Error for Reject {}

/// Admission configuration (`PoolConfig::admission`).  The default
/// admits everything a plain `submit` would: no cost seed (estimates
/// learn online from observed batches), one tenant (quota = whole
/// queue), unit slack.
#[derive(Clone, Debug)]
pub struct AdmissionCfg {
    /// Per-replica seed for the batch-cost estimate `ĉ_r` — one entry
    /// per replica, normally `SimBackendCfg::projected_batch_costs`
    /// (the §7-style cycle projection at each replica's precision).
    /// Empty = start at zero and learn from the first observed batch.
    pub batch_cost: Vec<Duration>,
    /// Declared tenant buckets for fair queuing; each tenant may hold
    /// at most `⌈queue_cap/tenants⌉` slots of any one shard.  `1`
    /// disables the quota.
    pub tenants: u32,
    /// Safety factor on the delay projection (finite, > 0).  Above 1
    /// rejects earlier (conservative), below 1 admits optimistically.
    pub slack: f64,
}

impl Default for AdmissionCfg {
    fn default() -> Self {
        AdmissionCfg { batch_cost: Vec::new(), tenants: 1, slack: 1.0 }
    }
}

/// EWMA weight of a newly observed batch cost (the seed keeps 1 − α).
const COST_EWMA_ALPHA: f64 = 0.2;

/// Runtime admission state shared between `submit_with` (charge +
/// project) and the replica workers (release + observe).  All state is
/// atomics: nothing here is ever held across an intake lock
/// (DESIGN.md §12 lock-order note).
pub struct Admission {
    /// Per-replica batch-cost estimate, f64 seconds in atomic bits.
    cost_bits: Vec<AtomicU64>,
    /// The constructor's per-replica seeds (f64 bits), kept so a
    /// respawned replica can be re-seeded instead of inheriting the
    /// EWMA its dead incarnation left behind (DESIGN.md §13).
    seed_bits: Vec<u64>,
    /// Occupancy table, `shard * tenants + (tenant % tenants)`.
    held: Vec<AtomicUsize>,
    tenants: u32,
    /// Max queue slots one tenant may hold per shard.
    quota: usize,
    slack: f64,
    /// Pool size the projection was sized for.
    replicas: usize,
    /// Currently live replicas (supervisor-maintained, DESIGN.md §13):
    /// the delay projection inflates by `replicas / healthy` so a
    /// degraded pool rejects earlier instead of promising capacity the
    /// dead replicas no longer provide.
    healthy: AtomicUsize,
}

impl Admission {
    /// Validate `cfg` against the pool shape and build the runtime
    /// state.  `batch_cost` must be empty or one entry per replica.
    pub fn new(cfg: &AdmissionCfg, replicas: usize, queue_cap: usize) -> Result<Self> {
        ensure!(cfg.tenants >= 1, "admission needs at least one tenant bucket");
        ensure!(
            cfg.slack.is_finite() && cfg.slack > 0.0,
            "admission slack must be finite and positive, got {}",
            cfg.slack
        );
        ensure!(
            cfg.batch_cost.is_empty() || cfg.batch_cost.len() == replicas,
            "admission batch_cost has {} entries for {} replicas (want 0 or {})",
            cfg.batch_cost.len(),
            replicas,
            replicas
        );
        let seed_bits: Vec<u64> = (0..replicas)
            .map(|r| {
                let s = cfg.batch_cost.get(r).map_or(0.0, |d| d.as_secs_f64());
                s.to_bits()
            })
            .collect();
        let cost_bits = seed_bits.iter().map(|&b| AtomicU64::new(b)).collect();
        let tenants = cfg.tenants;
        let quota = if tenants <= 1 {
            usize::MAX // single tenant: the queue cap is the only bound
        } else {
            (queue_cap.div_ceil(tenants as usize)).max(1)
        };
        let held = (0..replicas * tenants as usize).map(|_| AtomicUsize::new(0)).collect();
        Ok(Admission {
            cost_bits,
            seed_bits,
            held,
            tenants,
            quota,
            slack: cfg.slack,
            replicas,
            healthy: AtomicUsize::new(replicas),
        })
    }

    /// Current batch-cost estimate for replica `r`, seconds.
    pub fn batch_cost_s(&self, r: usize) -> f64 {
        f64::from_bits(self.cost_bits[r].load(Ordering::Relaxed))
    }

    /// Fold one observed batch wall time into replica `r`'s estimate
    /// (EWMA; a zero/unseeded estimate adopts the first observation).
    pub fn observe_batch_cost(&self, r: usize, dt_s: f64) {
        if !dt_s.is_finite() || dt_s <= 0.0 {
            return;
        }
        let cell = &self.cost_bits[r];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let old = f64::from_bits(cur);
            let new = if old <= 0.0 {
                dt_s
            } else {
                (1.0 - COST_EWMA_ALPHA) * old + COST_EWMA_ALPHA * dt_s
            };
            match cell.compare_exchange_weak(
                cur,
                new.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Fold a *partial-plane* batch observation into replica `r`'s
    /// estimate (DESIGN.md §15).  A refinement batch only executes
    /// `plane_frac` of a full batch's planes (residual / total bits),
    /// so its wall time is scaled up to the full-batch equivalent
    /// before entering the EWMA — otherwise a refinement-heavy window
    /// would teach admission that batches are cheap and over-admit the
    /// moment traffic shifts back to first runs.
    pub fn observe_partial_batch_cost(&self, r: usize, dt_s: f64, plane_frac: f64) {
        if !plane_frac.is_finite() || plane_frac <= 0.0 || plane_frac > 1.0 {
            return;
        }
        self.observe_batch_cost(r, dt_s / plane_frac);
    }

    /// Restore replica `r`'s batch-cost estimate to its constructor
    /// seed.  Called when the supervisor respawns a replica
    /// (DESIGN.md §13): the EWMA its dead incarnation accumulated —
    /// possibly poisoned by chaos jitter or a hang — must not gate
    /// admission against the fresh backend.
    pub fn reseed_cost(&self, r: usize) {
        if let (Some(cell), Some(&seed)) = (self.cost_bits.get(r), self.seed_bits.get(r)) {
            cell.store(seed, Ordering::Relaxed);
        }
    }

    /// Record how many replicas are currently live (clamped to the
    /// pool size).  The supervisor calls this on every health tick
    /// (DESIGN.md §13); the value scales [`projected_delay`] so a
    /// degraded pool stops promising full-pool capacity.
    ///
    /// [`projected_delay`]: Admission::projected_delay
    pub fn set_healthy_replicas(&self, n: usize) {
        self.healthy.store(n.min(self.replicas), Ordering::Relaxed);
    }

    /// Projected queue delay for a request landing on `shard` at queue
    /// depth `depth`: full batches ahead of it, plus the batch it
    /// joins, each at the shard's estimated cost, times the safety
    /// slack (DESIGN.md §12), inflated by `replicas / healthy` when the
    /// pool is degraded (§13) — with every replica down the projection
    /// is `Duration::MAX`, so any deadline is infeasible.
    pub fn projected_delay(&self, shard: usize, depth: usize, max_batch: usize) -> Duration {
        let healthy = self.healthy.load(Ordering::Relaxed);
        if healthy == 0 {
            return Duration::MAX;
        }
        let degraded = self.replicas as f64 / healthy as f64;
        let batches = (depth / max_batch.max(1)) as f64 + 1.0;
        let s = batches * self.batch_cost_s(shard) * self.slack * degraded;
        if s.is_finite() && s >= 0.0 {
            Duration::try_from_secs_f64(s).unwrap_or(Duration::MAX)
        } else {
            Duration::MAX
        }
    }

    /// Charge one queue slot of `shard` to `tenant`.  Fails with the
    /// observed `(held, quota)` when the tenant is at its per-shard
    /// quota.
    // lock-order: quota-touch
    pub fn try_charge(&self, shard: usize, tenant: u32) -> std::result::Result<(), (usize, usize)> {
        if self.quota == usize::MAX {
            return Ok(());
        }
        let cell = &self.held[self.slot(shard, tenant)];
        let quota = self.quota;
        cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |h| {
            if h < quota {
                Some(h + 1)
            } else {
                None
            }
        })
        .map(|_| ())
        .map_err(|h| (h, quota))
    }

    /// Release the slot charged by [`try_charge`]; `shard ==
    /// Item::TENANT_UNCHARGED` (or a single-tenant pool) is a no-op.
    ///
    /// [`try_charge`]: Admission::try_charge
    // lock-order: quota-touch
    pub fn release(&self, shard: u32, tenant: u32) {
        if self.quota == usize::MAX || shard == u32::MAX {
            return;
        }
        let cell = &self.held[self.slot(shard as usize, tenant)];
        let _ = cell.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |h| h.checked_sub(1));
    }

    /// Per-shard per-tenant quota (diagnostics; `usize::MAX` when fair
    /// queuing is off).
    pub fn quota(&self) -> usize {
        self.quota
    }

    fn slot(&self, shard: usize, tenant: u32) -> usize {
        shard * self.tenants as usize + (tenant % self.tenants) as usize
    }
}

/// PI controller configuration for closed-loop escalation-margin
/// tuning (`PoolConfig::escalation`, DESIGN.md §12).  Requires a
/// controller-tunable router (`escalate:auto`).
#[derive(Clone, Debug)]
pub struct EscalationController {
    /// Target escalation rate: fraction of first-run decisions that
    /// escalate, in (0, 1).
    pub budget: f64,
    /// Proportional gain, margin units per unit rate error.
    pub kp: f64,
    /// Integral gain, margin units per unit rate error per second.
    pub ki: f64,
    /// Controller period — also the width of the sliding metrics
    /// window the rate is measured over.
    pub interval: Duration,
    /// Clamp on the tuned margin, `(min, max)`.  Must be finite: an
    /// infinite bound would let the integrator push the margin to a
    /// value `Escalate` can never act on (every margin compares below
    /// `inf`), so `validate()` rejects it.
    pub bounds: (f32, f32),
    /// Minimum first-run decisions in a window before updating — the
    /// rate estimate over fewer samples is mostly noise.
    pub min_samples: u64,
}

impl EscalationController {
    /// Default gains for a given budget: fast enough to converge
    /// within a ~1 s bench window, damped enough not to oscillate
    /// around the margin distribution's steep quantiles.
    pub fn with_budget(budget: f64) -> Self {
        EscalationController {
            budget,
            kp: 0.4,
            ki: 4.0,
            interval: Duration::from_millis(5),
            bounds: (0.0, 4.0),
            min_samples: 8,
        }
    }

    /// Reject configurations the loop cannot safely run with.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.budget.is_finite() && self.budget > 0.0 && self.budget < 1.0,
            "escalation budget must be in (0, 1), got {}",
            self.budget
        );
        ensure!(
            self.kp.is_finite() && self.kp >= 0.0 && self.ki.is_finite() && self.ki >= 0.0,
            "controller gains must be finite and >= 0, got kp={} ki={}",
            self.kp,
            self.ki
        );
        ensure!(self.kp > 0.0 || self.ki > 0.0, "controller needs a non-zero gain");
        let (lo, hi) = self.bounds;
        ensure!(
            lo.is_finite() && hi.is_finite(),
            "margin bounds must be finite (an inf margin can never trigger an escalation), \
             got ({lo}, {hi})"
        );
        ensure!(
            lo >= 0.0 && lo < hi,
            "margin bounds must satisfy 0 <= min < max, got ({lo}, {hi})"
        );
        ensure!(
            self.interval > Duration::ZERO && self.interval <= Duration::from_secs(1),
            "controller interval must be in (0, 1s], got {:?}",
            self.interval
        );
        ensure!(self.min_samples >= 1, "controller needs min_samples >= 1");
        Ok(())
    }
}

/// Background PI loop: every `interval`, measure the escalation rate
/// from the `Metrics` counter deltas and nudge the shared margin knob
/// toward the budget.  Runs until `stop` is set (the server joins it
/// at shutdown).
pub(crate) fn run_margin_controller(
    ctl: EscalationController,
    knob: Arc<MarginKnob>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let mut last_esc = metrics.escalations.load(Ordering::Relaxed);
    let mut last_first = metrics.first_runs.load(Ordering::Relaxed);
    let mut window_s = 0.0f64;
    let mut prev_err = 0.0f64;
    let dt = ctl.interval.as_secs_f64();
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(ctl.interval);
        let esc = metrics.escalations.load(Ordering::Relaxed);
        let first = metrics.first_runs.load(Ordering::Relaxed);
        window_s += dt;
        // the window stays open (and keeps accumulating dt) until it
        // holds enough first-run decisions for a meaningful rate
        if first.saturating_sub(last_first) < ctl.min_samples {
            continue;
        }
        let rate = esc.saturating_sub(last_esc) as f64 / first.saturating_sub(last_first) as f64;
        (last_esc, last_first) = (esc, first);
        // err > 0: escalating below budget — raise the margin so more
        // replies qualify; err < 0: over budget — tighten it
        let err = ctl.budget - rate;
        let m = knob.get() as f64 + ctl.kp * (err - prev_err) + ctl.ki * err * window_s;
        prev_err = err;
        window_s = 0.0;
        knob.set(m.clamp(ctl.bounds.0 as f64, ctl.bounds.1 as f64) as f32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adm(tenants: u32, cap: usize) -> Admission {
        let cfg = AdmissionCfg { tenants, ..AdmissionCfg::default() };
        Admission::new(&cfg, 4, cap).unwrap()
    }

    #[test]
    fn projection_counts_batches_ahead_times_cost() {
        let cfg = AdmissionCfg {
            batch_cost: vec![Duration::from_millis(10); 2],
            ..AdmissionCfg::default()
        };
        let a = Admission::new(&cfg, 2, 64).unwrap();
        // empty queue: just the batch this request joins
        assert_eq!(a.projected_delay(0, 0, 8), Duration::from_millis(10));
        // 17 queued at max_batch 8 → 2 full batches ahead + own = 3
        assert_eq!(a.projected_delay(0, 17, 8), Duration::from_millis(30));
        // unseeded estimate would project 0 — admits optimistically
        let b = adm(1, 64);
        assert_eq!(b.projected_delay(0, 100, 8), Duration::ZERO);
    }

    #[test]
    fn slack_scales_the_projection() {
        let cfg = AdmissionCfg {
            batch_cost: vec![Duration::from_millis(10)],
            slack: 2.0,
            ..AdmissionCfg::default()
        };
        let a = Admission::new(&cfg, 1, 64).unwrap();
        assert_eq!(a.projected_delay(0, 0, 8), Duration::from_millis(20));
    }

    #[test]
    fn ewma_adopts_then_blends_observations() {
        let a = adm(1, 64);
        assert_eq!(a.batch_cost_s(0), 0.0);
        a.observe_batch_cost(0, 0.010); // unseeded: adopt
        assert!((a.batch_cost_s(0) - 0.010).abs() < 1e-12);
        a.observe_batch_cost(0, 0.020); // blend: 0.8·10ms + 0.2·20ms
        assert!((a.batch_cost_s(0) - 0.012).abs() < 1e-12);
        a.observe_batch_cost(0, f64::NAN); // garbage ignored
        a.observe_batch_cost(0, -1.0);
        assert!((a.batch_cost_s(0) - 0.012).abs() < 1e-12);
    }

    #[test]
    fn partial_batch_cost_scales_to_full_batch_equivalent() {
        let a = adm(1, 64);
        // a refinement batch that ran half the planes in 5ms teaches
        // the estimator that a full batch costs 10ms
        a.observe_partial_batch_cost(0, 0.005, 0.5);
        assert!((a.batch_cost_s(0) - 0.010).abs() < 1e-12);
        // frac 1.0 degenerates to the plain observation
        a.observe_partial_batch_cost(0, 0.010, 1.0);
        assert!((a.batch_cost_s(0) - 0.010).abs() < 1e-12);
        // garbage fractions are ignored, never divide-by-zero
        a.observe_partial_batch_cost(0, 0.005, 0.0);
        a.observe_partial_batch_cost(0, 0.005, -0.5);
        a.observe_partial_batch_cost(0, 0.005, 1.5);
        a.observe_partial_batch_cost(0, 0.005, f64::NAN);
        assert!((a.batch_cost_s(0) - 0.010).abs() < 1e-12);
    }

    #[test]
    fn reseed_restores_the_constructor_seed() {
        let cfg = AdmissionCfg {
            batch_cost: vec![Duration::from_millis(10); 2],
            ..AdmissionCfg::default()
        };
        let a = Admission::new(&cfg, 2, 64).unwrap();
        // a chaos-poisoned incarnation drags the EWMA way off
        for _ in 0..50 {
            a.observe_batch_cost(0, 5.0);
        }
        assert!(a.batch_cost_s(0) > 1.0);
        a.reseed_cost(0);
        assert!((a.batch_cost_s(0) - 0.010).abs() < 1e-12);
        // the sibling replica's estimate is untouched
        assert!((a.batch_cost_s(1) - 0.010).abs() < 1e-12);
        // unseeded pools reseed back to zero (learn-from-scratch)
        let b = adm(1, 64);
        b.observe_batch_cost(0, 0.5);
        b.reseed_cost(0);
        assert_eq!(b.batch_cost_s(0), 0.0);
        // out-of-range replica ids are a no-op, not a panic
        a.reseed_cost(99);
    }

    #[test]
    fn degraded_pool_inflates_the_projection() {
        let cfg = AdmissionCfg {
            batch_cost: vec![Duration::from_millis(10); 4],
            ..AdmissionCfg::default()
        };
        let a = Admission::new(&cfg, 4, 64).unwrap();
        assert_eq!(a.projected_delay(0, 0, 8), Duration::from_millis(10));
        // 2 of 4 replicas down: the survivors carry twice the load
        a.set_healthy_replicas(2);
        assert_eq!(a.projected_delay(0, 0, 8), Duration::from_millis(20));
        // nothing alive: every deadline is infeasible
        a.set_healthy_replicas(0);
        assert_eq!(a.projected_delay(0, 0, 8), Duration::MAX);
        // recovery restores the full-pool projection (clamped to pool size)
        a.set_healthy_replicas(100);
        assert_eq!(a.projected_delay(0, 0, 8), Duration::from_millis(10));
    }

    #[test]
    fn tenant_quota_charges_and_releases_per_shard() {
        // cap 8 over 2 tenants → quota 4 per shard
        let a = adm(2, 8);
        assert_eq!(a.quota(), 4);
        for _ in 0..4 {
            a.try_charge(0, 7).unwrap(); // tenant 7 → bucket 1
        }
        assert_eq!(a.try_charge(0, 7), Err((4, 4)));
        // other bucket and other shards are unaffected
        a.try_charge(0, 2).unwrap();
        a.try_charge(1, 7).unwrap();
        // release frees exactly one slot
        a.release(0, 7);
        a.try_charge(0, 7).unwrap();
        assert_eq!(a.try_charge(0, 7), Err((4, 4)));
        // sentinel / over-release are no-ops
        a.release(u32::MAX, 7);
        for _ in 0..20 {
            a.release(1, 2); // never charged: saturates at zero
        }
        a.try_charge(1, 2).unwrap();
    }

    #[test]
    fn single_tenant_pool_never_throttles() {
        let a = adm(1, 2);
        for _ in 0..100 {
            a.try_charge(0, 0).unwrap();
        }
    }

    #[test]
    fn admission_cfg_validation_is_descriptive() {
        let bad = AdmissionCfg { tenants: 0, ..AdmissionCfg::default() };
        let e = Admission::new(&bad, 2, 8).unwrap_err().to_string();
        assert!(e.contains("tenant"), "got: {e}");

        let bad = AdmissionCfg { slack: f64::INFINITY, ..AdmissionCfg::default() };
        let e = Admission::new(&bad, 2, 8).unwrap_err().to_string();
        assert!(e.contains("slack"), "got: {e}");

        let bad = AdmissionCfg {
            batch_cost: vec![Duration::from_millis(1); 3],
            ..AdmissionCfg::default()
        };
        let e = Admission::new(&bad, 2, 8).unwrap_err().to_string();
        assert!(e.contains("batch_cost") && e.contains("2 replicas"), "got: {e}");
    }

    #[test]
    fn controller_validation_rejects_inf_bounds_and_bad_budgets() {
        assert!(EscalationController::with_budget(0.25).validate().is_ok());

        // the satellite: a margin of inf smuggled in via controller
        // bounds must be rejected with a descriptive error
        let mut c = EscalationController::with_budget(0.25);
        c.bounds = (0.0, f32::INFINITY);
        let e = c.validate().unwrap_err().to_string();
        assert!(e.contains("finite"), "got: {e}");

        let mut c = EscalationController::with_budget(0.25);
        c.bounds = (2.0, 1.0);
        let e = c.validate().unwrap_err().to_string();
        assert!(e.contains("min < max"), "got: {e}");

        for bad in [0.0, 1.0, -0.5, f64::NAN] {
            let e = EscalationController::with_budget(bad).validate().unwrap_err().to_string();
            assert!(e.contains("budget"), "budget {bad}: {e}");
        }

        let mut c = EscalationController::with_budget(0.25);
        c.ki = f64::NAN;
        assert!(c.validate().unwrap_err().to_string().contains("gain"));

        let mut c = EscalationController::with_budget(0.25);
        c.interval = Duration::ZERO;
        assert!(c.validate().unwrap_err().to_string().contains("interval"));
    }

    #[test]
    fn reject_displays_are_descriptive() {
        let s = Reject::QueueFull { shard: 3, depth: 8, cap: 8 }.to_string();
        assert!(s.contains("queue full") && s.contains("shard 3"), "got: {s}");
        let s = Reject::DeadlineInfeasible {
            projected: Duration::from_millis(80),
            deadline: Duration::from_millis(20),
        }
        .to_string();
        assert!(s.contains("infeasible") && s.contains("80.000ms"), "got: {s}");
        let s = Reject::TenantThrottled { tenant: 9, shard: 1, held: 4, quota: 4 }.to_string();
        assert!(s.contains("tenant 9") && s.contains("4/4"), "got: {s}");
        let s = Reject::InvalidPayload { got: 3, want: 128 }.to_string();
        assert!(s.contains("3 elements") && s.contains("128"), "got: {s}");
    }
}
