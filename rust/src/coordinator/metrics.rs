//! Serving metrics: request/batch/error counters + latency percentiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::util::stats::{percentile, summarize};

/// Poison-recovering lock (same pattern as `GridLut::from_format`): a
/// worker that panicked mid-push can at worst leave a half-recorded
/// batch behind, which is strictly better than poisoning every future
/// metrics call in the server.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Shared, thread-safe metrics sink for the coordinator.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Batches whose execution failed end-to-end (every request in them
    /// received an error reply).  Success counters above are untouched
    /// by failures.
    pub errors: AtomicU64,
    latencies_s: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<usize>>,
}

/// Immutable snapshot for reporting.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub errors: u64,
    pub mean_batch: f64,
    pub lat_p50_ms: f64,
    pub lat_p95_ms: f64,
    pub lat_mean_ms: f64,
    pub throughput_rps: f64,
}

impl Metrics {
    pub fn record_batch(&self, size: usize, latency_s: f64, padded: usize) {
        self.requests.fetch_add(size as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots.fetch_add(padded as u64, Ordering::Relaxed);
        lock(&self.latencies_s).push(latency_s);
        lock(&self.batch_sizes).push(size);
    }

    /// A batch that failed end-to-end: count it in `errors` and record
    /// its latency (failed batches consume worker wall time too, so
    /// hiding them would bias the percentiles), leaving the
    /// success-only request/batch/padding counters untouched.
    pub fn record_error(&self, latency_s: f64) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        lock(&self.latencies_s).push(latency_s);
    }

    pub fn snapshot(&self, elapsed_s: f64) -> Snapshot {
        // one clone per series; the latency clone is sorted in place and
        // serves both the percentiles and the (order-insensitive) mean
        let mut lats = lock(&self.latencies_s).clone();
        let sizes = lock(&self.batch_sizes).clone();
        let requests = self.requests.load(Ordering::Relaxed);
        let (p50, p95, mean) = if lats.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (percentile(&lats, 50.0), percentile(&lats, 95.0), summarize(&lats).mean)
        };
        Snapshot {
            requests,
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            mean_batch: if sizes.is_empty() {
                0.0
            } else {
                sizes.iter().sum::<usize>() as f64 / sizes.len() as f64
            },
            lat_p50_ms: p50 * 1e3,
            lat_p95_ms: p95 * 1e3,
            lat_mean_ms: mean * 1e3,
            throughput_rps: if elapsed_s > 0.0 {
                requests as f64 / elapsed_s
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.record_batch(4, 0.010, 28);
        m.record_batch(2, 0.020, 30);
        let s = m.snapshot(1.0);
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.padded_slots, 58);
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
        assert!(s.lat_p95_ms > s.lat_p50_ms);
        assert!((s.throughput_rps - 6.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let m = Metrics::default();
        let s = m.snapshot(0.0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.errors, 0);
        assert_eq!(s.lat_p50_ms, 0.0);
    }

    #[test]
    fn record_error_counts_and_keeps_latency() {
        let m = Metrics::default();
        m.record_batch(4, 0.010, 0);
        m.record_error(0.500); // slow failed batch
        m.record_error(0.400);
        let s = m.snapshot(1.0);
        // failures never inflate the success counters…
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 1);
        assert_eq!(s.errors, 2);
        assert!((s.mean_batch - 4.0).abs() < 1e-12);
        // …but their wall time shows up in the latency series
        assert!(s.lat_p95_ms > 100.0, "p95 {} must see the failures", s.lat_p95_ms);
        assert!((s.lat_mean_ms - (10.0 + 500.0 + 400.0) / 3.0).abs() < 1e-9);
    }
}
