//! Serving metrics: request/batch counters + latency percentiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::{percentile, summarize};

/// Shared, thread-safe metrics sink for the coordinator.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    latencies_s: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<usize>>,
}

/// Immutable snapshot for reporting.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub mean_batch: f64,
    pub lat_p50_ms: f64,
    pub lat_p95_ms: f64,
    pub lat_mean_ms: f64,
    pub throughput_rps: f64,
}

impl Metrics {
    pub fn record_batch(&self, size: usize, latency_s: f64, padded: usize) {
        self.requests.fetch_add(size as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots.fetch_add(padded as u64, Ordering::Relaxed);
        self.latencies_s.lock().unwrap().push(latency_s);
        self.batch_sizes.lock().unwrap().push(size);
    }

    pub fn snapshot(&self, elapsed_s: f64) -> Snapshot {
        let lats = self.latencies_s.lock().unwrap().clone();
        let sizes = self.batch_sizes.lock().unwrap().clone();
        let requests = self.requests.load(Ordering::Relaxed);
        let (p50, p95, mean) = if lats.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            let mut s = lats.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (percentile(&s, 50.0), percentile(&s, 95.0), summarize(&lats).mean)
        };
        Snapshot {
            requests,
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            mean_batch: if sizes.is_empty() {
                0.0
            } else {
                sizes.iter().sum::<usize>() as f64 / sizes.len() as f64
            },
            lat_p50_ms: p50 * 1e3,
            lat_p95_ms: p95 * 1e3,
            lat_mean_ms: mean * 1e3,
            throughput_rps: if elapsed_s > 0.0 {
                requests as f64 / elapsed_s
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.record_batch(4, 0.010, 28);
        m.record_batch(2, 0.020, 30);
        let s = m.snapshot(1.0);
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.padded_slots, 58);
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
        assert!(s.lat_p95_ms > s.lat_p50_ms);
        assert!((s.throughput_rps - 6.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let m = Metrics::default();
        let s = m.snapshot(0.0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.lat_p50_ms, 0.0);
    }
}
