//! Serving metrics: request/batch/error counters + latency percentiles,
//! kept both globally and per replica (DESIGN.md §9), a queue-depth
//! gauge over the sharded intake, and the routing/stealing/escalation
//! counters of the heterogeneous pool (DESIGN.md §10).
//!
//! Accounting invariant (asserted by the coordinator e2e tests): every
//! request the server accepted ends in exactly one of four buckets —
//! `requests` (answered from a successful batch), `failed_requests`
//! (slot in a batch whose execution failed; the client got an `Err`
//! reply), `rejected` (invalid payload or admission refusal, answered
//! `Err`/typed `Reject` before execution), or `deadline_drops` (SLA
//! expired in the queue; `Err` reply at assembly, DESIGN.md §12) — so
//! `requests + failed_requests + rejected + deadline_drops` equals the
//! number of submitted requests once the queue drains.  An escalated
//! request (DESIGN.md §10) executes twice but is *answered* once: its
//! first run counts in the fast replica's `batches` only (never
//! `requests` — [`Metrics::record_batch_answered`] splits batch size
//! from replies sent), its re-run counts wherever it finally replies,
//! and the `escalations` counter records the hand-off itself.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::lock;
use crate::util::stats::{percentile, summarize};

/// Per-replica counters (one slot per pool worker).
#[derive(Default)]
pub struct ReplicaCounters {
    /// Successful batches this replica executed.
    pub batches: AtomicU64,
    /// Failed batches (every request in them got an error reply).
    pub errors: AtomicU64,
    /// Requests answered from this replica's successful batches.
    pub requests: AtomicU64,
    /// Requests the router assigned to this replica's queue at submit
    /// time (DESIGN.md §10).  Deterministic for the built-in routers:
    /// same seeded workload ⇒ same counts.
    pub routed: AtomicU64,
    /// Requests this replica pulled from sibling queue tails.
    pub stolen: AtomicU64,
    /// Escalation re-runs this replica *initiated* (low-margin replies
    /// it handed to the accurate tier instead of answering).
    pub escalations: AtomicU64,
    /// Escalations this replica *completed* as §15 refinements: cached
    /// partial sums plus residual planes, instead of a full re-run.
    pub refinements: AtomicU64,
    /// Requests this replica dropped at assembly because their SLA
    /// deadline expired in the queue (DESIGN.md §12).
    pub deadline_drops: AtomicU64,
    /// Times the supervisor respawned this replica's worker after a
    /// death or watchdog trip (DESIGN.md §13).
    pub restarts: AtomicU64,
}

/// Shared, thread-safe metrics sink for the coordinator.
pub struct Metrics {
    /// Requests answered from successful batches.
    pub requests: AtomicU64,
    /// Successful batches across the pool.
    pub batches: AtomicU64,
    /// Empty slots submitted alongside real requests when a batch was
    /// padded up to the backend's fixed shape.
    pub padded_slots: AtomicU64,
    /// Batches whose execution failed end-to-end (every request in them
    /// received an error reply).  Success counters above are untouched
    /// by failures.
    pub errors: AtomicU64,
    /// Requests that sat in failed batches (each got an `Err` reply).
    pub failed_requests: AtomicU64,
    /// Requests answered `Err` before execution (invalid payload — the
    /// worker refuses to zero-pad them into a fabricated class).
    pub rejected: AtomicU64,
    /// Escalation re-runs enqueued on the accurate tier (DESIGN.md §10).
    /// Counted when the hand-off lands in the target queue, so this is
    /// exactly the number of second executions the pool performed.
    pub escalations: AtomicU64,
    /// Escalations answered by adding residual bitplanes to cached
    /// partial sums instead of re-running from scratch (DESIGN.md §15).
    /// Informational, like `escalations`: a refined reply still counts
    /// in `requests` at the replica that finished it, so the four-bucket
    /// invariant is untouched.  `escalations - refinements` over a
    /// window is the number of hand-offs that paid the full 1× re-run
    /// (cache miss, dead source incarnation, or `refine:off`).
    pub refinements: AtomicU64,
    /// Requests whose SLA deadline expired while queued: answered `Err`
    /// at assembly, never executed (DESIGN.md §12).
    pub deadline_drops: AtomicU64,
    /// First-run decisions: requests that reached a verdict on their
    /// first execution (answered or escalated) in a successful batch.
    /// `escalations / first_runs` over a window is the escalation rate
    /// the §12 PI controller steers.
    pub first_runs: AtomicU64,
    /// Worker respawns performed by the supervisor across the pool
    /// (DESIGN.md §13).  A respawn is not a request-accounting event:
    /// the four-bucket invariant holds through every restart.
    pub restarts: AtomicU64,
    /// Replicas permanently retired after exhausting their restart
    /// budget; the pool keeps serving degraded on the survivors.
    pub retired: AtomicU64,
    /// Escalations whose preferred (most accurate live) target was
    /// unavailable and that fell down the precision ladder or answered
    /// with the fast result instead (DESIGN.md §13).
    pub failovers: AtomicU64,
    /// Queued items re-homed from a dead/retired replica's shard onto
    /// a compatible live shard by the failover drain.
    pub drained_requeues: AtomicU64,
    /// Gauge: requests accepted into the intake queue and not yet
    /// pulled into a batch by a replica.  Maintained by
    /// `queue_push`/`queue_pop`; returns to 0 once the pool drains.
    pub queue_depth: AtomicU64,
    per_replica: Vec<ReplicaCounters>,
    // lock-order: metrics level 1
    latencies_s: Mutex<Vec<f64>>,
    // lock-order: metrics level 2
    batch_sizes: Mutex<Vec<usize>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new(1)
    }
}

/// Per-replica slice of a [`Snapshot`].
#[derive(Clone, Debug)]
pub struct ReplicaSnapshot {
    /// Successful batches this replica executed.
    pub batches: u64,
    /// Failed batches on this replica.
    pub errors: u64,
    /// Requests answered by this replica.
    pub requests: u64,
    /// Requests the router assigned to this replica at submit time.
    pub routed: u64,
    /// Requests pulled from sibling queue tails.
    pub stolen: u64,
    /// Escalation re-runs this replica initiated.
    pub escalations: u64,
    /// Escalations this replica completed as §15 plane refinements.
    pub refinements: u64,
    /// Requests dropped at assembly with an expired SLA deadline.
    pub deadline_drops: u64,
    /// Supervisor respawns of this replica's worker.
    pub restarts: u64,
}

/// Immutable snapshot for reporting.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Requests answered from successful batches.
    pub requests: u64,
    /// Successful batches across the pool.
    pub batches: u64,
    /// Empty padding slots submitted with fixed-shape batches.
    pub padded_slots: u64,
    /// Failed batches (pool-wide).
    pub errors: u64,
    /// Requests that sat in failed batches (each got an `Err` reply).
    pub failed_requests: u64,
    /// Requests refused at admission (DESIGN.md §12).
    pub rejected: u64,
    /// Low-margin replies re-run on the accurate tier.
    pub escalations: u64,
    /// Escalations served as §15 refinements (residual planes added to
    /// cached partial sums) rather than full re-runs.
    pub refinements: u64,
    /// Requests dropped in-queue past their SLA deadline.
    pub deadline_drops: u64,
    /// Fast-tier first passes that preceded an escalation.
    pub first_runs: u64,
    /// Worker respawns across the pool (DESIGN.md §13).
    pub restarts: u64,
    /// Replicas permanently retired after exhausting restart budget.
    pub retired: u64,
    /// Shard failovers: a retired replica's queue handed to siblings.
    pub failovers: u64,
    /// Items re-queued onto siblings by failover drains.
    pub drained_requeues: u64,
    /// Items still queued at snapshot time.
    pub queue_depth: u64,
    /// Per-replica slices, indexed by replica id.
    pub per_replica: Vec<ReplicaSnapshot>,
    /// Mean successful batch size.
    pub mean_batch: f64,
    /// Median batch latency, milliseconds.
    pub lat_p50_ms: f64,
    /// 95th-percentile batch latency, milliseconds.
    pub lat_p95_ms: f64,
    /// Mean batch latency, milliseconds.
    pub lat_mean_ms: f64,
    /// Answered requests per second of wall-clock `elapsed_s`.
    pub throughput_rps: f64,
}

impl Snapshot {
    /// Multi-line per-replica report (one indented line per replica,
    /// labeled with its precision) — the single formatter behind the
    /// `dybit serve` printout and the serve example, so the shape the
    /// README documents cannot drift between them.
    pub fn replica_report(&self, precisions: &[super::router::ReplicaPrecision]) -> String {
        let mut out = String::new();
        for (i, r) in self.per_replica.iter().enumerate() {
            let p = precisions.get(i).copied().unwrap_or_default();
            out.push_str(&format!(
                "  replica {i} ({p}): {} routed, {} batches, {} requests, \
                 {} stolen, {} escalated-away, {} refined, {} deadline-dropped, \
                 {} errors\n",
                r.routed, r.batches, r.requests, r.stolen, r.escalations,
                r.refinements, r.deadline_drops, r.errors
            ));
        }
        out
    }
}

impl Metrics {
    /// Metrics sink with one per-replica counter slot per pool worker.
    pub fn new(replicas: usize) -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_slots: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            failed_requests: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            escalations: AtomicU64::new(0),
            refinements: AtomicU64::new(0),
            deadline_drops: AtomicU64::new(0),
            first_runs: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            retired: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            drained_requeues: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            per_replica: (0..replicas.max(1)).map(|_| ReplicaCounters::default()).collect(),
            latencies_s: Mutex::new(Vec::new()),
            batch_sizes: Mutex::new(Vec::new()),
        }
    }

    /// Number of replica slots this sink was built with.
    pub fn replicas(&self) -> usize {
        self.per_replica.len()
    }

    /// A successful batch executed by `replica` in which every request
    /// was answered (no escalations).
    pub fn record_batch(&self, replica: usize, size: usize, latency_s: f64, padded: usize) {
        self.record_batch_answered(replica, size, size, latency_s, padded);
    }

    /// A successful batch of `size` requests executed by `replica`, of
    /// which `answered` received replies here — the remaining
    /// `size - answered` were escalated to the accurate tier and count
    /// in `requests` only when their re-run replies (DESIGN.md §10;
    /// keeps `requests + failed_requests + rejected == submitted`).
    pub fn record_batch_answered(&self, replica: usize, size: usize, answered: usize,
                                 latency_s: f64, padded: usize) {
        let answered = answered.min(size);
        self.requests.fetch_add(answered as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.padded_slots.fetch_add(padded as u64, Ordering::Relaxed);
        if let Some(r) = self.per_replica.get(replica) {
            r.batches.fetch_add(1, Ordering::Relaxed);
            r.requests.fetch_add(answered as u64, Ordering::Relaxed);
        }
        lock(&self.latencies_s).push(latency_s);
        lock(&self.batch_sizes).push(size);
    }

    /// The router assigned one request to `replica`'s queue.
    pub fn record_routed(&self, replica: usize) {
        if let Some(r) = self.per_replica.get(replica) {
            r.routed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// `replica` pulled `n` requests from sibling queue tails.
    pub fn record_stolen(&self, replica: usize, n: usize) {
        if let Some(r) = self.per_replica.get(replica) {
            r.stolen.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// `replica` handed `n` low-margin replies to the accurate tier.
    pub fn record_escalated(&self, replica: usize, n: usize) {
        self.escalations.fetch_add(n as u64, Ordering::Relaxed);
        if let Some(r) = self.per_replica.get(replica) {
            r.escalations.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// `replica` completed `n` escalations as §15 plane refinements
    /// (cached partials + residual planes).  The replies themselves are
    /// recorded through [`Metrics::record_batch_answered`] as usual —
    /// this counter only classifies how the second execution was paid.
    pub fn record_refined(&self, replica: usize, n: usize) {
        self.refinements.fetch_add(n as u64, Ordering::Relaxed);
        if let Some(r) = self.per_replica.get(replica) {
            r.refinements.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// A batch of `size` requests that failed end-to-end on `replica`:
    /// count it in `errors`/`failed_requests` and record its latency
    /// (failed batches consume worker wall time too, so hiding them
    /// would bias the percentiles), leaving the success-only
    /// request/batch/padding counters untouched.
    pub fn record_error(&self, replica: usize, size: usize, latency_s: f64) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        self.failed_requests.fetch_add(size as u64, Ordering::Relaxed);
        if let Some(r) = self.per_replica.get(replica) {
            r.errors.fetch_add(1, Ordering::Relaxed);
        }
        lock(&self.latencies_s).push(latency_s);
    }

    /// A request answered `Err` before execution (invalid payload) or
    /// refused by admission with a typed `Reject` (DESIGN.md §12).
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// `replica` dropped `n` queue-expired requests at assembly (each
    /// got an `Err` reply; none executed).
    pub fn record_deadline_drops(&self, replica: usize, n: usize) {
        self.deadline_drops.fetch_add(n as u64, Ordering::Relaxed);
        if let Some(r) = self.per_replica.get(replica) {
            r.deadline_drops.fetch_add(n as u64, Ordering::Relaxed);
        }
    }

    /// `n` requests reached their first-run verdict (answered or
    /// escalated) in a successful batch — the denominator of the §12
    /// controller's escalation rate.
    pub fn record_first_decisions(&self, n: usize) {
        self.first_runs.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// `n` requests answered `Err` outside batch execution — a failover
    /// drain with no live compatible replica, or the shutdown sweep of
    /// stranded items (DESIGN.md §13).  They land in `failed_requests`
    /// so the §12 four-bucket invariant stays exact without fabricating
    /// a batch error or a latency sample.
    pub fn record_failed(&self, n: usize) {
        self.failed_requests.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// The supervisor respawned `replica`'s worker (DESIGN.md §13).
    pub fn record_restart(&self, replica: usize) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
        if let Some(r) = self.per_replica.get(replica) {
            r.restarts.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A replica exhausted its restart budget and was permanently
    /// retired; the pool now runs degraded without it.
    pub fn record_retired(&self) {
        self.retired.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` escalations could not reach their preferred accurate target
    /// and fell down the precision ladder (or answered with the fast
    /// result) instead.
    pub fn record_failovers(&self, n: usize) {
        self.failovers.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// `n` queued items were re-homed from a dead replica's shard onto
    /// live shards by the failover drain.
    pub fn record_drained_requeues(&self, n: usize) {
        self.drained_requeues.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// One request accepted into the intake queue.
    pub fn queue_push(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` requests pulled from the intake into a batch.  Saturating as
    /// a defensive backstop (pushes always precede the matching send,
    /// so a balanced caller never underflows; wrapping would turn any
    /// future accounting bug into a ~u64::MAX gauge).
    pub fn queue_pop(&self, n: usize) {
        let n = n as u64;
        let _ = self
            .queue_depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
                Some(d.saturating_sub(n))
            });
    }

    /// Freeze every counter plus derived latency/throughput stats
    /// (`elapsed_s` = wall-clock seconds the counters cover).
    pub fn snapshot(&self, elapsed_s: f64) -> Snapshot {
        // one clone per series; the latency clone is sorted in place and
        // serves both the percentiles and the (order-insensitive) mean
        let mut lats = lock(&self.latencies_s).clone();
        let sizes = lock(&self.batch_sizes).clone();
        let requests = self.requests.load(Ordering::Relaxed);
        let (p50, p95, mean) = if lats.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            // total_cmp, not partial_cmp().unwrap(): a NaN latency (e.g.
            // a clock anomaly) must not panic the metrics path
            lats.sort_unstable_by(f64::total_cmp);
            (percentile(&lats, 50.0), percentile(&lats, 95.0), summarize(&lats).mean)
        };
        Snapshot {
            requests,
            batches: self.batches.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            failed_requests: self.failed_requests.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            escalations: self.escalations.load(Ordering::Relaxed),
            refinements: self.refinements.load(Ordering::Relaxed),
            deadline_drops: self.deadline_drops.load(Ordering::Relaxed),
            first_runs: self.first_runs.load(Ordering::Relaxed),
            restarts: self.restarts.load(Ordering::Relaxed),
            retired: self.retired.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            drained_requeues: self.drained_requeues.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            per_replica: self
                .per_replica
                .iter()
                .map(|r| ReplicaSnapshot {
                    batches: r.batches.load(Ordering::Relaxed),
                    errors: r.errors.load(Ordering::Relaxed),
                    requests: r.requests.load(Ordering::Relaxed),
                    routed: r.routed.load(Ordering::Relaxed),
                    stolen: r.stolen.load(Ordering::Relaxed),
                    escalations: r.escalations.load(Ordering::Relaxed),
                    refinements: r.refinements.load(Ordering::Relaxed),
                    deadline_drops: r.deadline_drops.load(Ordering::Relaxed),
                    restarts: r.restarts.load(Ordering::Relaxed),
                })
                .collect(),
            mean_batch: if sizes.is_empty() {
                0.0
            } else {
                sizes.iter().sum::<usize>() as f64 / sizes.len() as f64
            },
            lat_p50_ms: p50 * 1e3,
            lat_p95_ms: p95 * 1e3,
            lat_mean_ms: mean * 1e3,
            throughput_rps: if elapsed_s > 0.0 {
                requests as f64 / elapsed_s
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let m = Metrics::default();
        m.record_batch(0, 4, 0.010, 28);
        m.record_batch(0, 2, 0.020, 30);
        let s = m.snapshot(1.0);
        assert_eq!(s.requests, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.padded_slots, 58);
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
        assert!(s.lat_p95_ms > s.lat_p50_ms);
        assert!((s.throughput_rps - 6.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let m = Metrics::default();
        let s = m.snapshot(0.0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.errors, 0);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.lat_p50_ms, 0.0);
        assert_eq!(s.per_replica.len(), 1);
    }

    #[test]
    fn record_error_counts_and_keeps_latency() {
        let m = Metrics::default();
        m.record_batch(0, 4, 0.010, 0);
        m.record_error(0, 3, 0.500); // slow failed batch
        m.record_error(0, 1, 0.400);
        let s = m.snapshot(1.0);
        // failures never inflate the success counters…
        assert_eq!(s.requests, 4);
        assert_eq!(s.batches, 1);
        assert_eq!(s.errors, 2);
        assert_eq!(s.failed_requests, 4);
        assert!((s.mean_batch - 4.0).abs() < 1e-12);
        // …but their wall time shows up in the latency series
        assert!(s.lat_p95_ms > 100.0, "p95 {} must see the failures", s.lat_p95_ms);
        assert!((s.lat_mean_ms - (10.0 + 500.0 + 400.0) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn per_replica_counters_sum_to_globals() {
        let m = Metrics::new(3);
        m.record_batch(0, 4, 0.010, 0);
        m.record_batch(1, 2, 0.011, 2);
        m.record_batch(1, 3, 0.012, 1);
        m.record_error(2, 4, 0.5);
        let s = m.snapshot(1.0);
        assert_eq!(s.per_replica.len(), 3);
        let b: u64 = s.per_replica.iter().map(|r| r.batches).sum();
        let e: u64 = s.per_replica.iter().map(|r| r.errors).sum();
        let q: u64 = s.per_replica.iter().map(|r| r.requests).sum();
        assert_eq!(b, s.batches);
        assert_eq!(e, s.errors);
        assert_eq!(q, s.requests);
        assert_eq!(s.per_replica[1].batches, 2);
        assert_eq!(s.per_replica[2].errors, 1);
    }

    #[test]
    fn out_of_range_replica_still_counts_globally() {
        // Default() has one slot; recording on a phantom replica id must
        // not panic and must keep the global counters correct.
        let m = Metrics::default();
        m.record_batch(7, 2, 0.01, 0);
        m.record_error(7, 1, 0.01);
        let s = m.snapshot(1.0);
        assert_eq!(s.requests, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.per_replica[0].batches, 0);
    }

    #[test]
    fn queue_gauge_tracks_and_saturates() {
        let m = Metrics::default();
        m.queue_push();
        m.queue_push();
        m.queue_push();
        assert_eq!(m.snapshot(1.0).queue_depth, 3);
        m.queue_pop(2);
        assert_eq!(m.snapshot(1.0).queue_depth, 1);
        m.queue_pop(5); // unbalanced pop clamps at zero
        assert_eq!(m.snapshot(1.0).queue_depth, 0);
    }

    #[test]
    fn batch_answered_splits_size_from_replies() {
        // a 4-request batch where 3 escalated: only 1 counts as answered,
        // the batch itself still counts (and its size feeds mean_batch)
        let m = Metrics::new(2);
        m.record_batch_answered(0, 4, 1, 0.010, 0);
        m.record_escalated(0, 3);
        // the accurate replica answers the 3 re-runs
        m.record_batch_answered(1, 3, 3, 0.020, 1);
        let s = m.snapshot(1.0);
        assert_eq!(s.requests, 4, "each submitted request answered exactly once");
        assert_eq!(s.batches, 2);
        assert_eq!(s.escalations, 3);
        assert_eq!(s.per_replica[0].requests, 1);
        assert_eq!(s.per_replica[0].escalations, 3);
        assert_eq!(s.per_replica[1].requests, 3);
        assert!((s.mean_batch - 3.5).abs() < 1e-12);
    }

    #[test]
    fn routed_and_stolen_counters_track() {
        let m = Metrics::new(3);
        m.record_routed(0);
        m.record_routed(0);
        m.record_routed(2);
        m.record_stolen(1, 2);
        let s = m.snapshot(1.0);
        assert_eq!(s.per_replica[0].routed, 2);
        assert_eq!(s.per_replica[1].routed, 0);
        assert_eq!(s.per_replica[2].routed, 1);
        assert_eq!(s.per_replica[1].stolen, 2);
        // phantom replica ids stay safe (same contract as record_batch)
        m.record_routed(9);
        m.record_stolen(9, 1);
        m.record_escalated(9, 1);
        assert_eq!(m.snapshot(1.0).escalations, 1);
    }

    #[test]
    fn deadline_drops_and_first_runs_count() {
        let m = Metrics::new(2);
        // 4-request batch: 1 answered, 3 escalated — 4 first decisions
        m.record_batch_answered(0, 4, 1, 0.010, 0);
        m.record_escalated(0, 3);
        m.record_first_decisions(4);
        // of the 3 re-runs, 2 answer and 1 expires in the queue
        m.record_batch_answered(1, 2, 2, 0.020, 0);
        m.record_deadline_drops(1, 1);
        let s = m.snapshot(1.0);
        assert_eq!(s.requests, 3);
        assert_eq!(s.deadline_drops, 1);
        assert_eq!(s.first_runs, 4);
        assert_eq!(s.per_replica[1].deadline_drops, 1);
        assert_eq!(s.per_replica[0].deadline_drops, 0);
        // the §12 invariant over this little history: 4 submitted =
        // 3 answered + 0 failed + 0 rejected + 1 deadline-dropped
        assert_eq!(s.requests + s.failed_requests + s.rejected + s.deadline_drops, 4);
        // phantom replica ids stay safe
        m.record_deadline_drops(9, 2);
        assert_eq!(m.snapshot(1.0).deadline_drops, 3);
    }

    #[test]
    fn selfheal_counters_track_without_touching_buckets() {
        // restarts/retired/failovers/drained_requeues are operational
        // counters — they must never perturb the four-bucket accounting
        let m = Metrics::new(2);
        m.record_batch(0, 4, 0.010, 0);
        m.record_restart(1);
        m.record_restart(1);
        m.record_retired();
        m.record_failovers(3);
        m.record_drained_requeues(5);
        let s = m.snapshot(1.0);
        assert_eq!(s.restarts, 2);
        assert_eq!(s.per_replica[1].restarts, 2);
        assert_eq!(s.per_replica[0].restarts, 0);
        assert_eq!(s.retired, 1);
        assert_eq!(s.failovers, 3);
        assert_eq!(s.drained_requeues, 5);
        assert_eq!(s.requests + s.failed_requests + s.rejected + s.deadline_drops, 4);
        // phantom replica ids stay safe (same contract as record_batch)
        m.record_restart(9);
        assert_eq!(m.snapshot(1.0).restarts, 3);
    }

    #[test]
    fn refinement_counter_tracks_without_touching_buckets() {
        // a refined escalation is: first run (0 answered of 1) on the
        // fast replica, then a refinement batch on the accurate one —
        // `refinements` classifies the second execution, the reply
        // itself still flows through record_batch_answered
        let m = Metrics::new(2);
        m.record_batch_answered(0, 1, 0, 0.010, 3);
        m.record_escalated(0, 1);
        m.record_first_decisions(1);
        m.record_refined(1, 1);
        m.record_batch_answered(1, 1, 1, 0.004, 3);
        let s = m.snapshot(1.0);
        assert_eq!(s.escalations, 1);
        assert_eq!(s.refinements, 1);
        assert_eq!(s.per_replica[0].refinements, 0, "initiator is not the refiner");
        assert_eq!(s.per_replica[1].refinements, 1);
        // the §12 invariant: 1 submitted = 1 answered, refinement is
        // informational and never a fifth bucket
        assert_eq!(s.requests + s.failed_requests + s.rejected + s.deadline_drops, 1);
        // phantom replica ids stay safe (same contract as record_batch)
        m.record_refined(9, 2);
        assert_eq!(m.snapshot(1.0).refinements, 3);
    }

    #[test]
    fn nan_latency_does_not_panic_snapshot() {
        // regression: the latency sort used partial_cmp().unwrap(), so a
        // single NaN sample panicked every later snapshot() call
        let m = Metrics::default();
        m.record_batch(0, 1, f64::NAN, 0);
        m.record_batch(0, 1, 0.010, 0);
        let s = m.snapshot(1.0);
        assert_eq!(s.requests, 2);
    }
}
