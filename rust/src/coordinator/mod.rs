//! L3 coordinator: the serving deployment of the quantized model —
//! bounded intake queue, dynamic batcher (size+deadline), PJRT worker,
//! latency/throughput metrics.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{Policy, Request};
pub use metrics::{Metrics, Snapshot};
pub use server::{load_test, Server, ServerConfig};
