//! L3 coordinator: the serving deployment of the quantized model
//! (DESIGN.md §9–§10).
//!
//! Request flow: [`Server::submit`] → [`Router`] picks a replica queue →
//! per-replica bounded FIFO ([`batcher::ShardedIntake`]) → dynamic
//! batching (size + deadline, idle replicas steal from sibling tails) →
//! a pool of replica workers over a pluggable [`InferenceBackend`]
//! (PJRT artifacts or the artifact-free simulator backend) → argmax +
//! margin → reply, or a one-shot escalation to the most accurate
//! replica when the margin is low.  [`Metrics`] tracks latency/
//! throughput plus per-replica batches, routing, stealing and
//! escalations.
//!
//! Replicas may differ in precision ([`ReplicaPrecision`]): a pool of
//! fast DyBit-4 replicas plus one 8-bit accurate replica recovers the
//! paper's Fig. 6 accuracy/latency trade-off at *serving* time
//! (DESIGN.md §10).  Under overload, [`Server::submit_with`] refuses
//! work with typed [`Reject`]s instead of blocking — SLA-projected
//! admission, per-tenant fair queuing, and a PI controller that tunes
//! the escalation margin onto a rate budget (DESIGN.md §12).  The pool
//! self-heals (DESIGN.md §13): replica heartbeats feed a supervisor
//! that respawns dead or wedged workers with capped backoff, retires
//! flappers, and fails traffic over to the live replicas — with
//! [`chaos::ChaosBackend`] injecting seeded faults to prove it.  On a
//! bitplane backend ([`BitplaneBackend`], DESIGN.md §15) escalation is
//! *refinement*: the fast replica parks its partial sums in a
//! [`PlaneCache`] and the accurate replica adds only the residual
//! planes — ~(extra-bits/total-bits) of a batch instead of a re-run.
//! Module map:
//!
//! | module | role | DESIGN.md |
//! |---|---|---|
//! | [`router`] | precision-aware queue selection + escalation policy | §10 |
//! | [`batcher`] | per-replica queues, batching, tail stealing | §9–§11 |
//! | [`backend`] | pluggable execution (`PjrtBackend`, `SimBackend`, bitplane `BitplaneBackend`) | §9, §15 |
//! | [`server`] | pool lifecycle, readiness, escalation + refinement, supervision | §9–§10, §13, §15 |
//! | [`metrics`] | counters, gauges, latency percentiles | §9–§10 |
//! | [`admission`] | SLA admission, tenant fair queuing, PI margin tuning | §12 |
//! | [`health`] | heartbeats, death watch, watchdog, backoff policy | §13 |
//! | [`chaos`] | seeded fault-injecting backend decorator | §13 |
//!
//! A minimal artifact-free pool (doc-tested; see [`Server::start_pool`]
//! for the heterogeneous version):
//!
//! ```
//! use dybit::coordinator::{PoolConfig, Server, SimBackend, SimBackendCfg};
//!
//! let pool = PoolConfig { replicas: 2, ..PoolConfig::default() };
//! let server = Server::start_pool(pool, SimBackend::factory(SimBackendCfg::tiny(1)))
//!     .unwrap();
//! assert_eq!(server.replicas(), 2);
//! let class = server.infer(vec![0.5; server.img_elems()]).unwrap();
//! assert!(class < 10);
//! let snap = server.shutdown().unwrap();
//! assert_eq!(snap.requests, 1);
//! assert_eq!(snap.queue_depth, 0);
//! ```

// The coordinator is the crate's public serving API surface: every
// exported item must say what it is (enforced; the rest of the crate
// is covered by the rustdoc link check in ci.sh).
#![deny(missing_docs)]

pub mod admission;
pub mod backend;
pub mod batcher;
pub mod chaos;
pub mod health;
pub mod metrics;
pub mod router;
pub mod server;

pub use admission::{Admission, AdmissionCfg, EscalationController, Reject, SubmitOpts};
pub use backend::{BackendFactory, BitplaneBackend, InferenceBackend, PjrtBackend,
                  PlaneCache, PlaneEntry, PlanePartial, SimBackend, SimBackendCfg,
                  SimCostMeter, SCORER_PLANES};
pub use batcher::{Assembled, CoarseIntake, IntakeQueue, Item, Policy, PushRefused, Request,
                  ShardedIntake};
pub use chaos::{ChaosBackend, ChaosSpec, Fault};
pub use health::{DeathWatch, HealthBoard, ReplicaState, SupervisionCfg};
pub use metrics::{Metrics, ReplicaSnapshot, Snapshot};
pub use router::{escalation_ladder, parse_precision_mix, resolve_precision_mix,
                 router_and_refine_from_spec, router_from_spec, AccuracyFloor, Escalate,
                 Fastest, MarginKnob, ReplicaPrecision, Router, DEFAULT_ESCALATE_MARGIN};
pub use server::{load_test, load_test_opts, LoadOpts, LoadReport, PoolConfig, Server,
                 ServerConfig};
