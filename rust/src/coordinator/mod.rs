//! L3 coordinator: the serving deployment of the quantized model —
//! bounded intake queue, dynamic batcher (size+deadline), a pool of
//! replica workers over a pluggable [`InferenceBackend`] (PJRT
//! artifacts or the artifact-free simulator backend), latency/
//! throughput/per-replica metrics (DESIGN.md §9).

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use backend::{BackendFactory, InferenceBackend, PjrtBackend, SimBackend, SimBackendCfg};
pub use batcher::{Policy, Request};
pub use metrics::{Metrics, ReplicaSnapshot, Snapshot};
pub use server::{load_test, PoolConfig, Server, ServerConfig};
